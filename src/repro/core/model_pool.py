"""ModelPool: the concrete neural-net parameter store (§3.2).

The paper runs M_M replicas behind a load balancer with everything
in-memory for instantaneous read/write. On one host that collapses to a
dict, but the API is the paper's: `pull`/`push` for the current learning
params (Actors pull theta and phi periodically; the Learner pushes theta),
`freeze` at learning-period end (theta joins the opponent pool M), and a
replica-pick hook preserved so the microservice semantics stay visible.

The pool is also the mint of the **param plane** (`repro.params`): every
push bumps a monotonic per-key `version`, and the first consumer that
asks gets a `ParamManifest` (per-leaf content hashes) for it — computed
lazily and cached per version, so a run that never syncs by version (the
`--sync` loop) never pays for hashing. `pull_if_changed(key,
have_version)` is the hash-gated pull: `NotModified` when the caller is
current, a changed-leaves `ParamDelta` when the server still holds the
manifest of the caller's version (a bounded history), a full pytree
otherwise.

Concurrency contract (the async league runtime hits this from every
worker thread):

* every operation is serialized under one lock — push/pull/freeze are
  linearizable, and a `pull_if_changed` can never observe a version
  whose params it does not also see;
* `snapshot_on_pull=True` makes `pull` (and the leaves of a
  `ParamDelta`) return deep copies of the stored pytree, so no caller
  can ever alias a buffer that another thread later hands to a donating
  train step (the PR 1 aliasing-bug class). Callers can override per
  call with `copy=...`.
* `membership_version` bumps whenever the key set changes — cheap
  signatures for callers (LeagueMgr's opponent cache) that want to
  revalidate membership incrementally instead of rescanning per task.
  Per-key `version` counters are independent of it: re-pushing an
  existing key bumps that key's version but not `membership_version`.
"""
from __future__ import annotations

import collections
import random
import threading
import time
from typing import Any, Dict, Optional, Union

from repro.core.types import ModelKey
from repro.params.manifest import (NotModified, ParamDelta, ParamManifest,
                                   build_manifest, flatten_with_paths)
from repro.utils.pytree import tree_copy

_MANIFEST_HISTORY = 16       # past manifests kept per key (hashes only)


class ModelPool:
    def __init__(self, num_replicas: int = 1, seed: int = 0,
                 snapshot_on_pull: bool = False):
        self.num_replicas = max(1, num_replicas)
        self.snapshot_on_pull = snapshot_on_pull
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._params: Dict[ModelKey, Any] = {}
        self._frozen: Dict[ModelKey, bool] = {}
        self._step: Dict[ModelKey, int] = {}
        self._versions: Dict[ModelKey, int] = {}          # monotonic per key
        self._manifest: Dict[ModelKey, ParamManifest] = {}  # current, lazy
        self._history: Dict[ModelKey, "collections.OrderedDict[int, ParamManifest]"] = {}
        self.membership_version = 0          # bumps when the key set changes
        self.read_counts = [0] * self.num_replicas  # replica load-balance bookkeeping
        # param-plane telemetry: how pulls were actually served
        # ("cross_key" counts answers where content addressing let some
        # leaves ride as hash references instead of bytes)
        self.pull_stats = {"full": 0, "delta": 0, "noop": 0, "cross_key": 0}

    def _pick_replica(self) -> int:
        r = self._rng.randrange(self.num_replicas)
        self.read_counts[r] += 1
        return r

    # -- API (paper protocol) -------------------------------------------------
    # Contract: every method here takes the pool lock and returns without
    # waiting on anything else — no pool call ever blocks beyond lock
    # contention (there is no capacity limit to wait on). Manifest hashing
    # happens lazily under the lock, once per (key, version), on the first
    # call that needs it.

    def push(self, key: ModelKey, params: Any, step: int = 0) -> None:
        """Store `params` under `key` and bump its version. Never blocks
        (lock only). The stored object is the caller's pytree, LIVE — the
        pool does not copy on push, so callers must hand over a snapshot
        if they keep mutating (the Learner's `_snapshot` does exactly
        that) and must never push buffers a donating train step may later
        consume."""
        with self._lock:
            if self._frozen.get(key):
                raise ValueError(f"model {key} is frozen; push refused")
            if key not in self._params:
                self.membership_version += 1
            self._params[key] = params
            self._step[key] = step
            self._versions[key] = self._versions.get(key, -1) + 1
            self._manifest.pop(key, None)    # re-minted lazily on next ask

    def pull(self, key: ModelKey, copy: Optional[bool] = None) -> Any:
        """Read `key`'s params. Never blocks (lock only). Snapshot vs live:
        with `copy=True` (or `copy=None` under a `snapshot_on_pull` pool)
        the caller gets a deep copy it can own outright; with `copy=False`
        it gets the LIVE stored object — read-only, and never safe to feed
        to a donating train step. Raises KeyError for unknown keys."""
        with self._lock:
            self._pick_replica()
            self.pull_stats["full"] += 1
            params = self._params[key]
            if self.snapshot_on_pull if copy is None else copy:
                params = tree_copy(params)
            return params

    def pull_if_changed(self, key: ModelKey,
                        have_version: Optional[int] = None,
                        copy: Optional[bool] = None,
                        have_hashes=None
                        ) -> Union[NotModified, ParamDelta]:
        """The hash-gated pull. With `have_version` equal to the current
        version the answer is a `NotModified` tag (nothing else moves).
        Otherwise a `ParamDelta`: changed leaves only, when the manifest
        of `have_version` is still in the bounded per-key history (it is
        whenever the caller obtained that version through this method);
        the full pytree when the caller's version is unknown, prehistoric,
        or the leaf set itself changed. Copy semantics of the returned
        arrays match `pull`. Raises KeyError for unknown keys.

        `have_hashes` (an iterable of leaf content hashes the caller
        holds — under ANY key) enables cross-key content addressing:
        leaves whose hash the caller advertised are answered as
        path->hash references (`ParamDelta.by_hash`) instead of bytes,
        on both the delta path and the would-be-full path. An exploiter
        reset that re-mints the seed pytree under a fresh key thus ships
        nothing to a consumer that ever held the seed."""
        with self._lock:
            self._pick_replica()
            params = self._params[key]          # KeyError for unknown keys
            man = self._current_manifest_locked(key)
            if have_version is not None and have_version == man.version:
                self.pull_stats["noop"] += 1
                return NotModified(version=man.version)
            snap = self.snapshot_on_pull if copy is None else copy
            have = frozenset(have_hashes) if have_hashes else frozenset()

            def split(paths, by_path):
                """Partition into shipped bytes vs hash references."""
                ship, by_hash = {}, {}
                for p in paths:
                    h = man.leaf_hashes[p]
                    if h in have:
                        by_hash[p] = h
                    else:
                        ship[p] = (tree_copy(by_path[p]) if snap
                                   else by_path[p])
                return ship, (by_hash or None)

            old = (self._history.get(key, {}).get(have_version)
                   if have_version is not None else None)
            if old is not None:
                changed = man.changed_paths(old)
                if changed is not None:
                    self.pull_stats["delta"] += 1
                    leaves, by_hash = split(changed,
                                            dict(flatten_with_paths(params)))
                    if by_hash:
                        self.pull_stats["cross_key"] += 1
                    return ParamDelta(manifest=man, full=False,
                                      leaves=leaves, by_hash=by_hash)
            if have:
                leaves, by_hash = split(list(man.leaf_hashes),
                                        dict(flatten_with_paths(params)))
                if by_hash:      # at least one leaf rides as a reference
                    self.pull_stats["cross_key"] += 1
                    return ParamDelta(manifest=man, full=False,
                                      leaves=leaves, by_hash=by_hash)
            self.pull_stats["full"] += 1
            return ParamDelta(manifest=man, full=True,
                              params=tree_copy(params) if snap else params)

    def _current_manifest_locked(self, key: ModelKey) -> ParamManifest:
        man = self._manifest.get(key)
        if man is None:
            man = build_manifest(self._params[key], self._versions[key])
            self._manifest[key] = man
            hist = self._history.setdefault(key, collections.OrderedDict())
            hist[man.version] = man
            while len(hist) > _MANIFEST_HISTORY:
                hist.popitem(last=False)
        return man

    def manifest(self, key: ModelKey) -> ParamManifest:
        """Current `ParamManifest` for `key` (minted now if needed)."""
        with self._lock:
            return self._current_manifest_locked(key)

    def version(self, key: ModelKey) -> int:
        """Current monotonic version of `key` (no hashing)."""
        with self._lock:
            if key not in self._params:
                raise KeyError(key)
            return self._versions[key]

    def pull_attr(self, key: ModelKey) -> dict:
        """Metadata snapshot (step counter, frozen flag, param-plane
        version); non-blocking."""
        with self._lock:
            return {"step": self._step.get(key, 0),
                    "frozen": self._frozen.get(key, False),
                    "version": self._versions.get(key, 0)}

    def install(self, key: ModelKey, params: Any, version: int,
                manifest: Optional[ParamManifest] = None, step: int = 0,
                frozen: bool = False) -> bool:
        """Replica-side adopt: store `params` AT an explicit version (the
        primary's), so a replica answers `pull_if_changed` with versions
        and hashes coherent with the primary — a client that cached v5
        from the primary gets a valid v5→v7 delta from a replica at v7.

        Monotonic guard: an install at or below the key's current version
        is refused (returns False) — a lagging sync can never regress the
        replica. Passing the primary's `manifest` skips local re-hashing
        and seeds the delta history. `frozen` mirrors the primary's
        write-bar only when set (never un-freezes)."""
        with self._lock:
            if key in self._params and version <= self._versions[key]:
                return False
            if key not in self._params:
                self.membership_version += 1
            self._params[key] = params
            self._step[key] = step
            self._versions[key] = version
            if manifest is not None:
                assert manifest.version == version, (manifest.version, version)
                self._manifest[key] = manifest
                hist = self._history.setdefault(key, collections.OrderedDict())
                hist[version] = manifest
                while len(hist) > _MANIFEST_HISTORY:
                    hist.popitem(last=False)
            else:
                self._manifest.pop(key, None)
            if frozen:
                self._frozen[key] = True
            return True

    def freeze(self, key: ModelKey) -> None:
        """Mark `key` immutable: later `push`es to it raise. Non-blocking;
        the params themselves are not copied — freezing is a write-bar,
        not a snapshot (and its version stops advancing, so every later
        `pull_if_changed` on it is a NotModified no-op)."""
        with self._lock:
            if key not in self._params:
                raise KeyError(key)
            self._frozen[key] = True

    def keys(self):
        """Snapshot list of hosted keys (stale the moment the lock drops —
        use `membership_version` to detect changes cheaply)."""
        with self._lock:
            return list(self._params)

    def __contains__(self, key: ModelKey):
        return key in self._params

    def __len__(self):
        return len(self._params)


class ModelPoolReplica:
    """A read replica: the paper's M_M ModelPool instances (§3.2), grown
    from one primary via the existing manifest/delta protocol.

    Wraps a *primary* (anything with the ModelPool pull surface — usually
    a `ModelPoolClient` over RPC) and keeps a local `ModelPool` in sync:
    each `sync_once` lists the primary's keys and runs every key through a
    `CachedPuller`, so an unchanged key costs one `NotModified` tag and a
    Learner publish arrives as a changed-leaves delta. Params are
    installed at the PRIMARY's version with the primary's manifest
    (`ModelPool.install`), so a consumer that cached v5 from the primary
    and fails over here gets a version-coherent v5→v7 delta, and a
    lagging replica can never regress below what it already serves.

    The replica object itself exposes the READ half of the pool protocol
    (serve it under the "pool" RPC namespace and `ModelPoolClient` works
    unchanged); writes raise — learners must push to the primary.
    """

    def __init__(self, primary, sync_interval_s: float = 0.5):
        from repro.params.cache import CachedPuller
        self._primary = primary
        self.pool = ModelPool(snapshot_on_pull=False)
        self._puller = CachedPuller(primary, copy=False)
        self.sync_interval_s = sync_interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.sync_stats = {"cycles": 0, "keys_installed": 0, "frozen_mirrored": 0,
                           "errors": 0, "last_ok_t": None}

    # -- follower ------------------------------------------------------------
    def sync_once(self) -> int:
        """One catch-up pass against the primary; returns how many keys
        changed locally. Raises whatever the primary transport raises —
        the follower loop counts and retries, one-shot callers decide."""
        installed = 0
        for key in self._primary.keys():
            params, man = self._puller.get_with_manifest(key)
            if man is None:
                continue                      # primary predates the param plane
            if self.pool.install(key, params, man.version, manifest=man):
                installed += 1
            attr = self._primary.pull_attr(key)
            # freeze only once the final weights are in hand: a frozen key
            # at an older local version keeps syncing until versions match
            if attr.get("frozen") and self.pool.version(key) >= attr["version"] \
                    and not self.pool.pull_attr(key)["frozen"]:
                self.pool.freeze(key)
                self.sync_stats["frozen_mirrored"] += 1
        self.sync_stats["cycles"] += 1
        self.sync_stats["keys_installed"] += installed
        self.sync_stats["last_ok_t"] = time.monotonic()
        return installed

    def _follow(self):
        while not self._stop.is_set():
            try:
                self.sync_once()
            except Exception:
                self.sync_stats["errors"] += 1
            self._stop.wait(self.sync_interval_s)

    def start_following(self) -> "ModelPoolReplica":
        assert self._thread is None, "already following"
        self._thread = threading.Thread(target=self._follow,
                                        name="pool-replica-sync", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    # -- read half of the pool protocol (servable under ns "pool") -----------
    def pull(self, key, copy=None):
        return self.pool.pull(key, copy=copy)

    def pull_if_changed(self, key, have_version=None, copy=None,
                        have_hashes=None):
        return self.pool.pull_if_changed(key, have_version, copy=copy,
                                         have_hashes=have_hashes)

    def manifest(self, key):
        return self.pool.manifest(key)

    def version(self, key):
        return self.pool.version(key)

    def pull_attr(self, key):
        return self.pool.pull_attr(key)

    def keys(self):
        return self.pool.keys()

    @property
    def membership_version(self):
        return self.pool.membership_version

    @property
    def pull_stats(self):
        return self.pool.pull_stats

    def __contains__(self, key):
        return key in self.pool

    def __len__(self):
        return len(self.pool)

    # -- writes are refused: this is a READ replica ---------------------------
    def push(self, key, params, step: int = 0):
        raise ValueError("read replica: push refused — write to the primary")

    def freeze(self, key):
        raise ValueError("read replica: freeze refused — write to the primary")
