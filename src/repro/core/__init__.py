"""The paper's primary contribution: league-based CSP-MARL machinery
(LeagueMgr, GameMgr opponent sampling, ModelPool, HyperMgr, payoff/Elo)."""
from repro.core.types import (ModelKey, Task, MatchResult, Hyperparam,
                              FreezeGate)
from repro.core.payoff import PayoffMatrix
from repro.core.model_pool import ModelPool, ModelPoolReplica
from repro.core.hyper_mgr import HyperMgr
from repro.core.game_mgr import (
    GameMgr, UniformGameMgr, PFSPGameMgr, SelfPlayPFSPGameMgr,
    EloMatchGameMgr, ExploiterGameMgr, LeagueExploiterGameMgr,
    MinimaxExploiterGameMgr, GAME_MGRS,
)
from repro.core.league_mgr import LeagueMgr, LearningAgent, ROLES, TaskLease
