"""League-protocol datatypes — the inter-module message contract (§3.3).

In the paper these are the private ZeroMQ RPC messages between LeagueMgr,
Actor, Learner and ModelPool; here they are the same protocol as dataclasses
passed over in-process queues (DESIGN.md §2, transport adaptation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

Outcome = int  # +1 win, 0 tie, -1 loss (from the learning agent's perspective)


@dataclass(frozen=True)
class ModelKey:
    """Identifies a frozen (or currently-learning) policy in the pool."""
    agent_id: str          # which learning agent produced it ("main", "exploiter:0", ...)
    version: int           # freeze counter within that agent's lineage

    def __str__(self):
        return f"{self.agent_id}:{self.version:04d}"


@dataclass
class Hyperparam:
    """Per-model hyperparameters the HyperMgr manages (and PBT perturbs)."""
    learning_rate: float = 3e-4
    gamma: float = 0.99
    lam: float = 0.95
    entropy_coef: float = 0.01
    clip_eps: float = 0.2
    # opponent-sampling knobs
    elo_sigma: float = 200.0        # Gaussian Elo-matching variance (PBT/Quake-III)
    pfsp_weighting: str = "squared"  # 'linear' | 'squared' | 'variance'

    def to_dict(self) -> Dict:
        return dict(self.__dict__)


@dataclass(frozen=True)
class FreezeGate:
    """When a learning model theta freezes into the opponent pool M.

    AlphaStar-style strength gating instead of a fixed period count: freeze
    once theta's aggregate winrate against the frozen pool reaches `winrate`
    (tau) with at least `min_games` of evidence, or after `timeout_steps`
    learner steps regardless. `step_gate`, when set, overrides everything
    with a pure step-count gate — the deterministic mode the sync/async
    equivalence tests rely on.
    """
    winrate: float = 0.7           # tau: freeze when pool winrate >= tau
    min_games: int = 16            # evidence needed before trusting winrate
    min_steps: int = 8             # never freeze before this many steps
    timeout_steps: int = 512       # freeze anyway after this many steps
    step_gate: Optional[int] = None  # pure step-count gate (determinism)

    def check(self, steps: int, pool_winrate: float,
              pool_games: float) -> Optional[str]:
        """Returns a freeze reason string, or None to keep training."""
        if self.step_gate is not None:
            return f"step_gate@{steps}" if steps >= self.step_gate else None
        if steps < self.min_steps:
            return None
        if pool_games >= self.min_games and pool_winrate >= self.winrate:
            return f"winrate@{pool_winrate:.3f}"
        if steps >= self.timeout_steps:
            return f"timeout@{steps}"
        return None

    def to_dict(self) -> Dict:
        return dict(self.__dict__)

    @classmethod
    def from_dict(cls, d: Dict) -> "FreezeGate":
        return cls(**d)


@dataclass(frozen=True)
class Task:
    """What LeagueMgr hands to an Actor (and, consistently, to the Learner):
    who learns, against whom, with which hyperparameters."""
    learner_key: ModelKey
    opponent_keys: Tuple[ModelKey, ...]   # >=1; FSP extends to multi-opponent
    hyperparam: Hyperparam
    task_id: int = 0


@dataclass(frozen=True)
class MatchResult:
    """Episode outcome reported by an Actor at episode end.

    `task_id` echoes the Task the episode was played under; -1 marks
    legacy/eval traffic that never held a lease. The LeagueMgr's lease
    plane uses it as a generation guard: results quoting a reaped lease
    are dropped instead of corrupting the payoff matrix."""
    learner_key: ModelKey
    opponent_keys: Tuple[ModelKey, ...]
    outcome: Outcome
    episode_len: int = 0
    info: Optional[Dict] = None
    task_id: int = -1
