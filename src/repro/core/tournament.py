"""Round-robin tournament over the frozen pool: fills the payoff matrix and
ranks models — the league-evaluation tooling the GameMgr's opponent
sampling consumes (and how a finished league is analyzed, cf. the paper's
win-rate tables and AlphaStar's league payoff plots).

Rankings:
  - Elo (incremental, from PayoffMatrix)
  - mean win-rate (row average of the payoff matrix)
  - Nash-averaging-lite: iterative proportional fitness (replicator steps
    on the empirical payoff), far cheaper than an LP and adequate for
    ranking a pool of tens of models.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core.payoff import PayoffMatrix
from repro.core.types import MatchResult, ModelKey


def round_robin(payoff: PayoffMatrix, models: Sequence[ModelKey],
                play: Callable[[ModelKey, ModelKey, int], int],
                episodes_per_pair: int = 4, seed: int = 0) -> PayoffMatrix:
    """play(a, b, episode_idx) -> outcome (+1 a wins / 0 / -1). Fills the
    payoff matrix with every unordered pair."""
    for m in models:
        payoff.add_model(m)
    for i, a in enumerate(models):
        for b in models[i + 1:]:
            for ep in range(episodes_per_pair):
                out = play(a, b, ep)
                payoff.record(MatchResult(learner_key=a, opponent_keys=(b,),
                                          outcome=int(out)))
    return payoff


def replicator_ranking(payoff: PayoffMatrix, iters: int = 200,
                       lr: float = 0.5) -> Dict[ModelKey, float]:
    """Replicator-dynamics fixed point on the win-rate matrix: the mass a
    model holds at convergence is its equilibrium weight (Nash-averaging
    lite). Uniform for an empty matrix."""
    models = payoff.models
    n = len(models)
    if n == 0:
        return {}
    W = payoff.matrix() - 0.5          # antisymmetric advantage matrix
    p = np.ones(n) / n
    for _ in range(iters):
        fitness = W @ p
        p = p * np.exp(lr * fitness)
        p = np.clip(p, 1e-12, None)
        p /= p.sum()
    return dict(zip(models, p))


def league_report(payoff: PayoffMatrix) -> dict:
    models = payoff.models
    M = payoff.matrix()
    mean_wr = {m: float(M[i].sum() - M[i, i]) / max(len(models) - 1, 1)
               for i, m in enumerate(models)}
    nash = replicator_ranking(payoff)
    return {
        "models": [str(m) for m in models],
        "elo": {str(m): round(payoff.elo[m], 1) for m in models},
        "mean_winrate": {str(m): round(v, 3) for m, v in mean_wr.items()},
        "nash_weight": {str(m): round(float(v), 3) for m, v in nash.items()},
        "best_by_elo": str(max(models, key=lambda m: payoff.elo[m])) if models else None,
        "best_by_nash": str(max(nash, key=nash.get)) if nash else None,
    }
