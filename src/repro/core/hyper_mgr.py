"""HyperMgr: per-model hyperparameters + PBT perturbation (§3.2).

Each model theta_i in the pool carries its own Hyperparam (learning rate,
gamma, Elo-matching sigma, z-statistics-like extras...). PBT [Jaderberg et
al. 2019] exploit/explore: a poorly-performing learner copies a stronger
population member's hypers and perturbs them multiplicatively.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterable

from repro.core.types import Hyperparam, ModelKey

PERTURBABLE = ("learning_rate", "entropy_coef", "clip_eps")


class HyperMgr:
    def __init__(self, default: Hyperparam | None = None, seed: int = 0,
                 perturb_factor: float = 1.2):
        self.default = default or Hyperparam()
        self._hypers: Dict[ModelKey, Hyperparam] = {}
        self._rng = random.Random(seed)
        self.perturb_factor = perturb_factor

    def register(self, key: ModelKey, hyper: Hyperparam | None = None) -> Hyperparam:
        h = hyper or dataclasses.replace(self.default)
        self._hypers[key] = h
        return h

    def get(self, key: ModelKey) -> Hyperparam:
        if key not in self._hypers:
            return self.register(key)
        return self._hypers[key]

    def inherit(self, child: ModelKey, parent: ModelKey) -> Hyperparam:
        h = dataclasses.replace(self.get(parent))
        self._hypers[child] = h
        return h

    # -- PBT -----------------------------------------------------------------
    def explore(self, key: ModelKey) -> Hyperparam:
        """Multiplicative perturbation of the perturbable fields."""
        h = self.get(key)
        updates = {}
        for f in PERTURBABLE:
            v = getattr(h, f)
            factor = self.perturb_factor if self._rng.random() < 0.5 else 1.0 / self.perturb_factor
            updates[f] = v * factor
        h2 = dataclasses.replace(h, **updates)
        self._hypers[key] = h2
        return h2

    def exploit_explore(self, weak: ModelKey, strong: ModelKey) -> Hyperparam:
        self.inherit(weak, strong)
        return self.explore(weak)
