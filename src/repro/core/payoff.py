"""Payoff matrix + Elo ratings over the model pool (GameMgr's state, §3.2).

Maintains win/tie/loss counts for every (row=learner lineage model,
col=opponent model) pair, exposes win-rates (ties = half win, as the paper's
Pommerman evaluation counts them) and incremental Elo updates used by
PBT/Elo-matched opponent sampling [Jaderberg et al. 2019].
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.types import MatchResult, ModelKey


class PayoffMatrix:
    def __init__(self, elo_k: float = 16.0, init_elo: float = 1200.0):
        self.models: List[ModelKey] = []
        self._index: Dict[ModelKey, int] = {}
        self._wins = np.zeros((0, 0), np.float64)
        self._ties = np.zeros((0, 0), np.float64)
        self._losses = np.zeros((0, 0), np.float64)
        self.elo: Dict[ModelKey, float] = {}
        self.elo_k = elo_k
        self.init_elo = init_elo

    # -- pool growth ---------------------------------------------------------
    def add_model(self, key: ModelKey, init_elo: float | None = None):
        if key in self._index:
            return
        self._index[key] = len(self.models)
        self.models.append(key)
        n = len(self.models)
        for name in ("_wins", "_ties", "_losses"):
            m = getattr(self, name)
            grown = np.zeros((n, n), np.float64)
            grown[: m.shape[0], : m.shape[1]] = m
            setattr(self, name, grown)
        self.elo[key] = self.init_elo if init_elo is None else init_elo

    def __contains__(self, key: ModelKey):
        return key in self._index

    def __len__(self):
        return len(self.models)

    # -- updates ---------------------------------------------------------------
    def record(self, result: MatchResult):
        i = self._index[result.learner_key]
        for opp in result.opponent_keys:
            j = self._index[opp]
            if result.outcome > 0:
                self._wins[i, j] += 1
                self._losses[j, i] += 1
            elif result.outcome < 0:
                self._losses[i, j] += 1
                self._wins[j, i] += 1
            else:
                self._ties[i, j] += 1
                self._ties[j, i] += 1
            self._update_elo(result.learner_key, opp, result.outcome)

    def _update_elo(self, a: ModelKey, b: ModelKey, outcome: int):
        ra, rb = self.elo[a], self.elo[b]
        ea = 1.0 / (1.0 + 10 ** ((rb - ra) / 400.0))
        sa = 0.5 + 0.5 * outcome
        self.elo[a] = ra + self.elo_k * (sa - ea)
        self.elo[b] = rb + self.elo_k * ((1.0 - sa) - (1.0 - ea))

    # -- queries -----------------------------------------------------------------
    def games(self, a: ModelKey, b: ModelKey) -> float:
        i, j = self._index[a], self._index[b]
        return self._wins[i, j] + self._ties[i, j] + self._losses[i, j]

    def winrate(self, a: ModelKey, b: ModelKey, prior: float = 0.5,
                prior_games: float = 2.0) -> float:
        """P(a beats b), ties half-counted, with a Beta-style prior so unseen
        pairs look 50/50 instead of 0 or NaN."""
        i, j = self._index[a], self._index[b]
        w = self._wins[i, j] + 0.5 * self._ties[i, j] + prior * prior_games
        n = self.games(a, b) + prior_games
        return float(w / n)

    def winrates_vs(self, a: ModelKey, opponents: Sequence[ModelKey]) -> np.ndarray:
        return np.array([self.winrate(a, o) for o in opponents])

    def matrix(self) -> np.ndarray:
        """Full win-rate matrix (rows beat cols)."""
        n = len(self.models)
        out = np.full((n, n), 0.5)
        for i, a in enumerate(self.models):
            for j, b in enumerate(self.models):
                if i != j and self.games(a, b) > 0:
                    out[i, j] = self.winrate(a, b)
        return out

    def to_state(self) -> dict:
        return {
            "models": [str(m) for m in self.models],
            "wins": self._wins, "ties": self._ties, "losses": self._losses,
            "elo": {str(k): v for k, v in self.elo.items()},
        }
