"""Payoff matrix + Elo ratings over the model pool (GameMgr's state, §3.2).

Maintains win/tie/loss counts for every (row=learner lineage model,
col=opponent model) pair, exposes win-rates (ties = half win, as the paper's
Pommerman evaluation counts them) and incremental Elo updates used by
PBT/Elo-matched opponent sampling [Jaderberg et al. 2019].

Storage is a set of preallocated (cap, cap) count arrays with amortized
geometric growth (add_model is O(1) amortized, not a full reallocation per
model), queries are pure NumPy array ops over the live (n, n) views, and
`record_many` ingests tournament result floods with one `np.add.at` per
count matrix instead of a per-result Python loop.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.types import MatchResult, ModelKey


class _EloView:
    """Dict-like view over the rating vector (keeps the paper-era
    `payoff.elo[key]` API while the storage is a NumPy array)."""

    def __init__(self, payoff: "PayoffMatrix"):
        self._p = payoff

    def __getitem__(self, key: ModelKey) -> float:
        return float(self._p._elo[self._p._index[key]])

    def __setitem__(self, key: ModelKey, value: float) -> None:
        self._p._elo[self._p._index[key]] = value

    def get(self, key: ModelKey, default=None):
        i = self._p._index.get(key)
        return default if i is None else float(self._p._elo[i])

    def __contains__(self, key: ModelKey) -> bool:
        return key in self._p._index

    def __len__(self) -> int:
        return len(self._p.models)

    def __iter__(self) -> Iterator[ModelKey]:
        return iter(self._p.models)

    def items(self) -> Iterator[Tuple[ModelKey, float]]:
        for k in self._p.models:
            yield k, self[k]

    def values(self) -> Iterator[float]:
        for k in self._p.models:
            yield self[k]

    def keys(self) -> Iterator[ModelKey]:
        return iter(self._p.models)


class PayoffMatrix:
    def __init__(self, elo_k: float = 16.0, init_elo: float = 1200.0):
        self.models: List[ModelKey] = []
        self._index: Dict[ModelKey, int] = {}
        self._cap = 0
        self._wins = np.zeros((0, 0), np.float64)
        self._ties = np.zeros((0, 0), np.float64)
        self._losses = np.zeros((0, 0), np.float64)
        self._elo = np.zeros((0,), np.float64)
        self.elo = _EloView(self)
        self.elo_k = elo_k
        self.init_elo = init_elo

    # -- pool growth ---------------------------------------------------------
    def _grow_to(self, cap: int) -> None:
        new_cap = max(4, self._cap)
        while new_cap < cap:
            new_cap *= 2
        if new_cap == self._cap:
            return
        n = len(self.models)
        for name in ("_wins", "_ties", "_losses"):
            m = getattr(self, name)
            grown = np.zeros((new_cap, new_cap), np.float64)
            grown[:n, :n] = m[:n, :n]
            setattr(self, name, grown)
        elo = np.full((new_cap,), self.init_elo, np.float64)
        elo[:n] = self._elo[:n]
        self._elo = elo
        self._cap = new_cap

    def add_model(self, key: ModelKey, init_elo: float | None = None):
        if key in self._index:
            return
        i = len(self.models)
        if i >= self._cap:
            self._grow_to(i + 1)
        self._index[key] = i
        self.models.append(key)
        self._elo[i] = self.init_elo if init_elo is None else init_elo

    def __contains__(self, key: ModelKey):
        return key in self._index

    def __len__(self):
        return len(self.models)

    # -- live (n, n) count views ----------------------------------------------
    @property
    def wins(self) -> np.ndarray:
        n = len(self.models)
        return self._wins[:n, :n]

    @property
    def ties(self) -> np.ndarray:
        n = len(self.models)
        return self._ties[:n, :n]

    @property
    def losses(self) -> np.ndarray:
        n = len(self.models)
        return self._losses[:n, :n]

    # -- updates ---------------------------------------------------------------
    def record(self, result: MatchResult):
        self.record_many((result,))

    def record_many(self, results: Iterable[MatchResult]) -> None:
        """Batched ingest for tournament result floods: one `np.add.at`
        scatter per count matrix. Elo stays sequential over results (each
        update reads the ratings the previous one wrote), but operates on
        the rating array directly."""
        ii: List[int] = []
        jj: List[int] = []
        oo: List[int] = []
        elo = self._elo
        k_factor = self.elo_k
        for r in results:
            i = self._index[r.learner_key]
            for opp in r.opponent_keys:
                j = self._index[opp]
                ii.append(i)
                jj.append(j)
                oo.append(r.outcome)
                ra, rb = elo[i], elo[j]
                ea = 1.0 / (1.0 + 10 ** ((rb - ra) / 400.0))
                sa = 0.5 + 0.5 * r.outcome
                elo[i] = ra + k_factor * (sa - ea)
                elo[j] = rb + k_factor * ((1.0 - sa) - (1.0 - ea))
        if not ii:
            return
        i_arr, j_arr = np.asarray(ii), np.asarray(jj)
        o_arr = np.asarray(oo)
        w, t, l = o_arr > 0, o_arr == 0, o_arr < 0
        np.add.at(self._wins, (i_arr[w], j_arr[w]), 1.0)
        np.add.at(self._wins, (j_arr[l], i_arr[l]), 1.0)
        np.add.at(self._losses, (i_arr[l], j_arr[l]), 1.0)
        np.add.at(self._losses, (j_arr[w], i_arr[w]), 1.0)
        np.add.at(self._ties, (i_arr[t], j_arr[t]), 1.0)
        np.add.at(self._ties, (j_arr[t], i_arr[t]), 1.0)

    # -- queries -----------------------------------------------------------------
    def games(self, a: ModelKey, b: ModelKey) -> float:
        i, j = self._index[a], self._index[b]
        return float(self._wins[i, j] + self._ties[i, j] + self._losses[i, j])

    def winrate(self, a: ModelKey, b: ModelKey, prior: float = 0.5,
                prior_games: float = 2.0) -> float:
        """P(a beats b), ties half-counted, with a Beta-style prior so unseen
        pairs look 50/50 instead of 0 or NaN."""
        i, j = self._index[a], self._index[b]
        w = self._wins[i, j] + 0.5 * self._ties[i, j] + prior * prior_games
        n = self.games(a, b) + prior_games
        return float(w / n)

    def winrates_vs(self, a: ModelKey, opponents: Sequence[ModelKey],
                    prior: float = 0.5, prior_games: float = 2.0) -> np.ndarray:
        """Vectorized winrate(a, o) over a candidate list (PFSP hot path)."""
        i = self._index[a]
        js = np.fromiter((self._index[o] for o in opponents), np.intp,
                         count=len(opponents))
        w = self._wins[i, js] + 0.5 * self._ties[i, js] + prior * prior_games
        g = self._wins[i, js] + self._ties[i, js] + self._losses[i, js]
        return w / (g + prior_games)

    def aggregate_vs(self, a: ModelKey,
                     opponents: Sequence[ModelKey]) -> Tuple[float, float]:
        """(winrate, games) of `a` aggregated over all games against
        `opponents` — the freeze-gate signal (ties half-counted; 0.5 with
        zero evidence). Unknown keys contribute nothing."""
        i = self._index.get(a)
        js = [self._index[o] for o in opponents
              if o in self._index and o != a]
        if i is None or not js:
            return 0.5, 0.0
        js = np.asarray(js, np.intp)
        w = float(self._wins[i, js].sum())
        t = float(self._ties[i, js].sum())
        g = w + t + float(self._losses[i, js].sum())
        return ((w + 0.5 * t) / g if g > 0 else 0.5), g

    def matrix(self, prior: float = 0.5, prior_games: float = 2.0) -> np.ndarray:
        """Full win-rate matrix (rows beat cols), one array expression:
        played off-diagonal pairs get the prior-smoothed rate, everything
        else (unseen pairs and the diagonal) sits at 0.5."""
        n = len(self.models)
        W, T, L = self.wins, self.ties, self.losses
        G = W + T + L
        rate = (W + 0.5 * T + prior * prior_games) / (G + prior_games)
        played = G > 0
        np.fill_diagonal(played, False)
        return np.where(played, rate, 0.5)

    def to_state(self) -> dict:
        return {
            "models": [str(m) for m in self.models],
            "wins": self.wins.copy(), "ties": self.ties.copy(),
            "losses": self.losses.copy(),
            "elo": {str(k): v for k, v in self.elo.items()},
        }
