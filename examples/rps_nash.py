"""Reproduces the paper's §3.1 motivating claim: on Rock-Paper-Scissors,
INDEPENDENT RL circulates (pure-rock -> pure-paper -> pure-scissors,
forgetting how to beat older policies), while FICTITIOUS SELF-PLAY
(opponent sampled from the historical pool) converges toward the uniform
Nash equilibrium.

  PYTHONPATH=src python examples/rps_nash.py [--iters 30]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import LeagueMgr, UniformGameMgr
from repro.core.game_mgr import GameMgr, register_game_mgr
from repro.envs import make_env
from repro.learners import Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw
from repro.actors.policy import make_obs_policy


@register_game_mgr("independent")
class IndependentGameMgr(GameMgr):
    """Independent RL: always play the CURRENT opponent (no pool mixing)."""

    def get_opponent(self, learner_key, candidates):
        return learner_key


def action_distribution(cfg, env, params):
    policy = make_obs_policy(cfg, env.spec.num_actions)
    # observation at episode start: opponent_last=3 (none), parity token 4
    obs = jnp.array([[3, 4]], jnp.int32)
    lg, _ = policy.logits_values(params, obs)
    return np.asarray(jax.nn.softmax(lg[0]))


def run(mode, iters, freeze_every=4, seed=0):
    cfg = get_arch("tleague-policy-s")
    env = make_env("rps", episode_len=4)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    league = LeagueMgr(seed=seed)
    gm = (IndependentGameMgr() if mode == "independent"
          else UniformGameMgr(recent_n=50))
    league.add_learning_agent("main", params, game_mgr=gm)
    actor = Actor(env, cfg, league, num_envs=32, unroll_len=8, seed=seed)
    opt = adamw(1e-3, clip_norm=1.0)
    step = build_env_train_step(cfg, env.spec.num_actions, opt)
    learner = Learner(league, step, opt, params)

    dists = []
    for it in range(iters):
        traj, _ = actor.run_segment()
        learner.data_server.put(traj)
        learner.learn()
        if (it + 1) % freeze_every == 0:
            learner.end_learning_period()
        dists.append(action_distribution(cfg, env, learner.params))
    return np.stack(dists)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=24)
    args = ap.parse_args()

    print("=== independent RL (expected: circulation / collapse) ===")
    d_ind = run("independent", args.iters)
    print("=== FSP via league (expected: -> uniform NE [1/3,1/3,1/3]) ===")
    d_fsp = run("fsp", args.iters)

    for name, d in [("independent", d_ind), ("fsp", d_fsp)]:
        tail = d[-5:].mean(0)
        dev = np.abs(tail - 1 / 3).max()
        peak = d.max(1).mean()   # how 'pure' the policy tends to be
        print(f"{name:12}: final dist={np.round(tail, 3)} "
              f"max|p - 1/3|={dev:.3f} avg peak prob={peak:.3f}")
    print("(FSP should sit closer to uniform; independent RL drifts to "
          "near-pure strategies and cycles between freezes.)")


if __name__ == "__main__":
    main()
