"""Quickstart: the full TLeague loop in ~40 lines.

Builds a league (LeagueMgr + ModelPool + HyperMgr + PFSP GameMgr), one Actor
producing trajectories against sampled opponents, one PPO Learner consuming
them, runs two learning periods with freezes, and prints the league state +
throughput (the paper's rfps/cfps).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import LeagueMgr, SelfPlayPFSPGameMgr
from repro.envs import make_env
from repro.learners import Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw


def main():
    cfg = get_arch("tleague-policy-s")          # TPolicies-scale policy net
    env = make_env("rps")                       # §3.1's motivating game
    params = init_params(jax.random.PRNGKey(0), cfg)

    league = LeagueMgr()
    league.add_learning_agent("main", params,
                              game_mgr=SelfPlayPFSPGameMgr(payoff=None))
    actor = Actor(env, cfg, league, num_envs=16, unroll_len=8)
    opt = adamw(3e-4, clip_norm=1.0)
    train_step = build_env_train_step(cfg, env.spec.num_actions, opt)
    learner = Learner(league, train_step, opt, params)

    for period in range(2):
        for it in range(8):
            traj, task = actor.run_segment()    # Actor: request task, rollout
            learner.data_server.put(traj)       # ship the segment
            metrics = learner.learn()           # Learner: consume + SGD
            if it % 4 == 0:
                print(f"period {period} it {it}: "
                      f"loss={float(metrics['loss']):.3f} "
                      f"entropy={float(metrics['entropy']):.3f} "
                      f"opp={task.opponent_keys[0]}")
        new_key = learner.end_learning_period() # freeze theta into the pool
        print(f"period {period} done -> now training {new_key}")

    print("league state:", league.league_state())
    print("throughput:", learner.data_server.throughput())


if __name__ == "__main__":
    main()
