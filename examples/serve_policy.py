"""Serving example: the InfServer path (paper §3.2) with a big-arch backbone.

Demonstrates the two serving steps the decode-shape dry-runs lower:
prefill (batch of observation-token prompts -> KV cache) + autoregressive
serve_step decode — using the reduced gemma2 variant so it runs on CPU,
then the batched InfServer front-end serving many actor clients.

  PYTHONPATH=src python examples/serve_policy.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.infserver import InfServer
from repro.models import decode_step, init_params, prefill


def main():
    cfg = get_arch("gemma2-2b").smoke()      # local+global pattern, softcaps
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, T, new_tokens = 4, 32, 8

    # 1) prefill: batch of prompts -> last-position logits + KV cache
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    logits, values, state = jax.jit(
        lambda p, b: prefill(p, cfg, b))(params, {"tokens": toks})
    print(f"prefill: logits {logits.shape}, cache length "
          f"{int(state['length'][0])}")

    # 2) autoregressive decode with the cache (the serve_step the
    #    decode_32k / long_500k dry-run shapes lower at production scale)
    dstep = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(new_tokens):
        lg, _, state = dstep(params, tok, state)
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)[..., 0:1]
        out.append(tok)
    dt = (time.perf_counter() - t0) / new_tokens
    print(f"decode: {new_tokens} steps, {dt*1e3:.1f} ms/token/batch, "
          f"tokens[0] = {[int(t[0, 0]) for t in out]}")

    # 3) the batched InfServer front-end (SEED-style central inference)
    server = InfServer(cfg, num_actions=16, params=params, max_batch=32)
    tickets = [server.submit(np.zeros((1, 8), np.int32)) for _ in range(32)]
    acts = [server.get(t)[0] for t in tickets]
    print(f"infserver: served {server.requests_served} requests in "
          f"{server.batches_run} batched forward(s); actions[0:8] = "
          f"{[int(a[0]) for a in acts[:8]]}")


if __name__ == "__main__":
    main()
