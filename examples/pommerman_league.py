"""End-to-end driver (paper §4.3): 2v2 Pommerman-lite team CSP training with
the AlphaStar-style 35% self-play / 65% PFSP mixture, a main agent + an
exploiter, periodic freezes, PBT hyper perturbation, and a win-rate
evaluation vs the scripted SimpleAgent after every period (the paper's
Fig. 4 curve).

  PYTHONPATH=src python examples/pommerman_league.py --periods 3 --steps 24
"""
import argparse

import numpy as np

from repro.configs import get_arch
from repro.envs import make_env
from repro.envs.scripted import pommerman_simple_bot
from repro.eval import learned_policy_fn, play_episodes, winrate_vs
from repro.launch.train import run_league_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--periods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--envs", type=int, default=8)
    ap.add_argument("--eval-episodes", type=int, default=8)
    args = ap.parse_args()

    curve = []
    cfg = get_arch("tleague-policy-s")
    env = make_env("pommerman_lite")

    for p in range(args.periods):
        league, agents, _ = run_league_training(
            env_name="pommerman_lite", arch="tleague-policy-s",
            game_mgr="sp_pfsp", periods=p + 1, steps_per_period=args.steps,
            num_envs=args.envs, unroll_len=16, num_exploiters=1, pbt=True,
            verbose=(p == 0))
        _, learner = agents["main"]
        me = learned_policy_fn(cfg, env.spec.num_actions, learner.params)
        res = play_episodes(env, [me, me, pommerman_simple_bot,
                                  pommerman_simple_bot],
                            episodes=args.eval_episodes, seed=100 + p)
        wr = winrate_vs(res["outcomes"])
        curve.append(wr)
        print(f"[fig4] after {p+1} periods: winrate vs SimpleAgent = {wr:.2f} "
              f"(outcomes {res['outcomes'].tolist()})")
        print(f"       league: {league.league_state()}")

    print("win-rate curve:", np.round(curve, 2).tolist())


if __name__ == "__main__":
    main()
