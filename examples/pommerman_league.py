"""End-to-end driver (paper §4.3): 2v2 Pommerman-lite team CSP training with
the AlphaStar-style 35% self-play / 65% PFSP mixture, built from a
LeagueSpec — one `main` role plus one `minimax_exploiter` (the
data-efficient exploiter curriculum of arXiv:2311.17190) — with periodic
freezes, exploiter reset-on-freeze, PBT hyper perturbation, and a win-rate
evaluation vs the scripted SimpleAgent after every period (the paper's
Fig. 4 curve).

  PYTHONPATH=src python examples/pommerman_league.py --periods 3 --steps 24

`--async-seconds N` swaps the deterministic lockstep loop for the
event-driven league runtime (threads + winrate-gated freezes) for N
seconds per period instead.
"""
import argparse

import numpy as np

from repro.configs import get_arch
from repro.core import FreezeGate
from repro.envs import make_env
from repro.envs.scripted import pommerman_simple_bot
from repro.eval import learned_policy_fn, play_episodes, winrate_vs
from repro.league import LeagueSpec, RoleSpec
from repro.launch.train import run_league_training, run_league_training_async


def build_spec(steps_per_period: int) -> LeagueSpec:
    """One main + one minimax exploiter chasing it. The gate freezes on
    pool winrate >= tau (or a step timeout), and the exploiter restarts
    from its seed at every freeze (AlphaStar reset semantics)."""
    return LeagueSpec(roles=(
        RoleSpec(name="main", role="main",
                 gate=FreezeGate(winrate=0.7, min_games=16, min_steps=8,
                                 timeout_steps=max(8, steps_per_period))),
        RoleSpec(name="exploiter:0", role="minimax_exploiter", target="main",
                 matchmaking_kwargs={"beat_threshold": 0.6},
                 gate=FreezeGate(winrate=0.6, min_games=16, min_steps=8,
                                 timeout_steps=max(8, steps_per_period))),
    ))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--periods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--envs", type=int, default=8)
    ap.add_argument("--eval-episodes", type=int, default=8)
    ap.add_argument("--async-seconds", type=float, default=None,
                    help="run the event-driven runtime for this many "
                         "seconds per period instead of the lockstep loop")
    args = ap.parse_args()

    curve = []
    cfg = get_arch("tleague-policy-s")
    env = make_env("pommerman_lite")
    spec = build_spec(args.steps)

    for p in range(args.periods):
        if args.async_seconds:
            league, runtime, report = run_league_training_async(
                spec, env_name="pommerman_lite", arch="tleague-policy-s",
                num_envs=args.envs, unroll_len=16, pbt=True,
                max_seconds=args.async_seconds * (p + 1),
                verbose=(p == 0))
            learner = runtime.roles[0].learner.learner
        else:
            league, agents, _ = run_league_training(
                env_name="pommerman_lite", arch="tleague-policy-s",
                periods=p + 1, steps_per_period=args.steps,
                num_envs=args.envs, unroll_len=16, pbt=True,
                league_spec=spec, verbose=(p == 0))
            _, learner = agents["main"]
        me = learned_policy_fn(cfg, env.spec.num_actions, learner.params)
        res = play_episodes(env, [me, me, pommerman_simple_bot,
                                  pommerman_simple_bot],
                            episodes=args.eval_episodes, seed=100 + p)
        wr = winrate_vs(res["outcomes"])
        curve.append(wr)
        print(f"[fig4] after {p+1} periods: winrate vs SimpleAgent = {wr:.2f} "
              f"(outcomes {res['outcomes'].tolist()})")
        print(f"       league: {league.league_state()}")

    print("win-rate curve:", np.round(curve, 2).tolist())


if __name__ == "__main__":
    main()
