"""Roofline analysis (deliverable g): read the dry-run JSONs and derive the
three terms per (arch x shape x mesh):

  compute    = HLO_FLOPs_total / (chips x 197e12 FLOP/s)
  memory     = HLO_bytes_total / (chips x 819e9 B/s)
  collective = collective_bytes_total / (chips x 50e9 B/s)

cost_analysis/HLO are per-partition (per-chip) programs, so totals are
per-chip x chips; the per-chip time is the per-chip quantity / per-chip
rate — identical either way; we report seconds directly from the per-chip
numbers. Scan-body undercounting is fixed upstream by the dry-run's 2-point
unrolled extrapolation ("measured"). MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) for train; 2*N*D forward-only for prefill/decode.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(rec: dict) -> float:
    """Per-chip useful model FLOPs for the step."""
    from repro.configs import get_arch
    from repro.configs.base import INPUT_SHAPES
    cfg = get_arch(rec["arch"])
    shp = INPUT_SHAPES[rec["shape"]]
    n = cfg.active_param_count()
    if rec["kind"] in ("train", "mlm_train"):
        tokens = shp.global_batch * shp.seq_len
        f = 6.0 * n * tokens
    elif rec["kind"] == "prefill":
        f = 2.0 * n * shp.global_batch * shp.seq_len
    else:  # decode: one token per sequence
        f = 2.0 * n * shp.global_batch * 1
    return f / rec["chips"]


def _recurrence_flops(rec: dict) -> float:
    """Analytic per-chip FLOPs of SSM time recurrences — their lax.scan over
    T is counted once by XLA cost analysis (documented undercount), so we
    add the closed form: rwkv6 ~6*d*hs per token-layer; mamba ~6*d_in*N."""
    from repro.configs import get_arch
    from repro.configs.base import INPUT_SHAPES
    cfg = get_arch(rec["arch"])
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    shp = INPUT_SHAPES[rec["shape"]]
    tokens = shp.global_batch * (shp.seq_len if rec["kind"] in
                                 ("train", "mlm_train", "prefill") else 1)
    if cfg.family == "ssm":
        per_tok_layer = 6.0 * cfg.d_model * cfg.ssm.head_size
    else:
        per_tok_layer = 6.0 * (cfg.ssm.expand * cfg.d_model) * cfg.ssm.state_size
    mult = 3.0 if rec["kind"] in ("train", "mlm_train") else 1.0  # fwd+bwd
    return per_tok_layer * cfg.num_layers * tokens * mult / rec["chips"]


def analyze(rec: dict) -> dict:
    m = rec.get("measured") or {}
    flops = m.get("flops") or rec["cost"].get("flops", 0.0)
    byts = m.get("bytes") or rec["cost"].get("bytes accessed", 0.0)
    if byts <= 0:  # 2-point extrapolation can go negative on tiny models
        byts = rec["cost"].get("bytes accessed", 0.0)
    coll = m.get("collective_bytes")
    if coll is None or coll < 0:
        coll = rec["collectives"]["total"]
    flops += _recurrence_flops(rec)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_i = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_i),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_i,
        "bottleneck": dom,
        "model_flops": mf,
        "useful_frac": (mf / flops) if flops else 0.0,
        "roofline_frac": t_c / max(t_c, t_m, t_i) if max(t_c, t_m, t_i) else 0.0,
        "hlo_flops": flops, "hlo_bytes": byts, "coll_bytes": coll,
    }


def load_all(dirpath="experiments/dryrun") -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            recs.append(analyze(r))
        elif r.get("status") == "skip":
            recs.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "skip": r.get("reason", "skip")})
    return recs


def table(recs: List[dict]) -> str:
    hdr = (f"| {'arch':24} | {'shape':11} | {'mesh':7} | {'kind':9} | "
           f"{'compute_s':>10} | {'memory_s':>9} | {'collect_s':>9} | "
           f"{'bottleneck':10} | {'useful':>6} |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    rows = [hdr, sep]
    for r in recs:
        if "skip" in r:
            rows.append(f"| {r['arch']:24} | {r['shape']:11} | {r['mesh']:7} | "
                        f"{'SKIP':9} | {r['skip'][:46]:>46} |")
            continue
        rows.append(
            f"| {r['arch']:24} | {r['shape']:11} | {r['mesh']:7} | "
            f"{r['kind']:9} | {r['compute_s']:10.4f} | {r['memory_s']:9.4f} | "
            f"{r['collective_s']:9.4f} | {r['bottleneck']:10} | "
            f"{r['useful_frac']:6.2f} |")
    return "\n".join(rows)


def main():
    recs = load_all()
    print(table(recs))
    # CSV lines for benchmarks/run.py protocol
    for r in recs:
        if "skip" in r:
            continue
        step_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        print(f"roofline/{r['arch']}/{r['shape']},{step_us:.1f},"
              f"bottleneck={r['bottleneck']};useful={r['useful_frac']:.2f}")


if __name__ == "__main__":
    main()
