"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  table3_throughput   — rfps / cfps / repeat ratio per env (paper Table 3)
  table3_scaleup      — rfps vs actor (env) count: the scale-up claim
  seed_infserver      — batched InfServer vs local batch-1 forwards (§3.2)
  infserver_throughput— central batched inference vs per-actor forwards at
                        64 simulated actors; writes BENCH_infserver.json
                        (the paper's Table-3-style serving claim as a
                        tracked number)
  table12_league_eval — league-trained agent vs scripted bots (Tables 1-2)
  fig4_winrate        — win-rate vs training iterations (Fig. 4), short run
  kernels             — Pallas kernel microbenches (interpret-mode on CPU:
                        correctness-path timing; TPU-target timing comes
                        from the roofline, see benchmarks/roofline.py)
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _time(fn, iters=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
def table3_throughput():
    """Paper Table 3: rfps (actor producing) and cfps (learner consuming)."""
    from repro.actors import Actor
    from repro.configs import get_arch
    from repro.core import LeagueMgr
    from repro.envs import make_env
    from repro.learners import Learner, build_env_train_step
    from repro.models import init_params
    from repro.optim import adamw

    for env_name, num_envs, unroll in [("rps", 32, 8),
                                       ("pommerman_lite", 8, 16),
                                       ("duel", 8, 16)]:
        cfg = get_arch("tleague-policy-s")
        env = make_env(env_name)
        params = init_params(jax.random.PRNGKey(0), cfg)
        league = LeagueMgr()
        league.add_learning_agent("main", params)
        actor = Actor(env, cfg, league, num_envs=num_envs, unroll_len=unroll)
        opt = adamw(3e-4)
        step = build_env_train_step(cfg, env.spec.num_actions, opt)
        learner = Learner(league, step, opt, params)
        actor.run_segment()  # compile
        t0 = time.perf_counter()
        n_seg = 4
        for _ in range(n_seg):
            traj, _ = actor.run_segment()
            learner.data_server.put(traj)
            learner.learn()
        dt = time.perf_counter() - t0
        frames = n_seg * num_envs * unroll
        tp = learner.data_server.throughput()
        us = dt / n_seg * 1e6
        _emit(f"table3/{env_name}", us,
              f"rfps={frames/dt:.0f};cfps={tp['cfps']:.0f};"
              f"repeat={tp['repeat_ratio']:.2f}")


def table3_scaleup():
    """rfps vs parallel-env count (the paper's actor scale-up axis)."""
    from repro.actors.rollout import build_rollout
    from repro.configs import get_arch
    from repro.envs import make_env
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    env = make_env("rps")
    params = init_params(jax.random.PRNGKey(0), cfg)
    base_rfps = None
    for n in (4, 16, 64):
        rollout, init_carry = build_rollout(env, cfg, num_envs=n, unroll_len=8)
        carry = init_carry(jax.random.PRNGKey(1))
        r = jax.random.PRNGKey(2)
        jax.block_until_ready(rollout(params, params, carry, r)[1]["actions"])
        t0 = time.perf_counter()
        iters = 3
        for i in range(iters):
            carry, traj, _ = rollout(params, params, carry,
                                     jax.random.fold_in(r, i))
        jax.block_until_ready(traj["actions"])
        dt = (time.perf_counter() - t0) / iters
        rfps = n * 8 / dt
        base_rfps = base_rfps or rfps
        _emit(f"table3_scaleup/envs{n}", dt * 1e6,
              f"rfps={rfps:.0f};scaleup_x={rfps/base_rfps:.2f}")


def seed_infserver():
    """SEED claim (§3.2): batched central inference beats batch-1 locals."""
    from repro.configs import get_arch
    from repro.infserver import InfServer
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InfServer(cfg, 6, params, max_batch=64)
    obs = np.zeros((1, 26), np.int32)
    server.get(server.submit(obs))  # compile batch-1 path
    us_local = _time(lambda: server.get(server.submit(obs)), iters=16)

    def batched():
        tickets = [server.submit(obs) for _ in range(64)]
        for t in tickets:
            server.get(t)

    batched()  # compile batch-64 path
    us_batch = _time(batched, iters=4) / 64
    _emit("seed_infserver/local_b1", us_local, "per_request")
    _emit("seed_infserver/batched64", us_batch,
          f"per_request;speedup_x={us_local/us_batch:.1f}")


def infserver_throughput(num_actors: int = 64, out_path: str | None = None):
    """Central batched inference vs per-actor batch-1 forwards with
    `num_actors` simulated clients (§3.2 / Table 3 serving claim). Writes
    the result to BENCH_infserver.json so the >=2x speedup is tracked."""
    from repro.actors.policy import make_obs_policy
    from repro.configs import get_arch
    from repro.infserver import InfServer
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    num_actions, obs_len = 6, 26
    obs1 = np.zeros((1, obs_len), np.int32)

    # baseline: every simulated actor runs its own batch-1 forward
    policy = make_obs_policy(cfg, num_actions)
    local_act = jax.jit(policy.act)
    rng = jax.random.PRNGKey(1)
    jax.block_until_ready(local_act(params, rng, jnp.asarray(obs1)))

    def per_actor_round():
        for i in range(num_actors):
            a, _, _ = local_act(params, jax.random.fold_in(rng, i),
                                jnp.asarray(obs1))
        jax.block_until_ready(a)

    us_local = _time(per_actor_round, iters=4) / num_actors

    # central: the same num_actors requests ride one continuous batch
    server = InfServer(cfg, num_actions, params, max_batch=num_actors)

    def central_round():
        tickets = [server.submit(obs1) for _ in range(num_actors)]
        for t in tickets:
            server.get(t)

    central_round()  # compile the batched path
    us_central = _time(central_round, iters=4) / num_actors

    speedup = us_local / us_central
    stats = server.stats()
    record = {
        "num_actors": num_actors,
        "per_actor_us_per_request": round(us_local, 2),
        "central_batched_us_per_request": round(us_central, 2),
        "speedup_x": round(speedup, 2),
        "server_occupancy": round(stats["occupancy"], 4),
        "server_mean_batch_rows": stats["mean_batch_rows"],
        "server_mean_batch_latency_ms": round(
            stats["mean_batch_latency_ms"], 3),
        "arch": "tleague-policy-s",
    }
    path = pathlib.Path(out_path) if out_path else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_infserver.json"
    path.write_text(json.dumps(record, indent=2) + "\n")
    _emit(f"infserver/per_actor{num_actors}", us_local, "per_request")
    _emit(f"infserver/central{num_actors}", us_central,
          f"per_request;speedup_x={speedup:.1f};wrote={path.name}")
    return record


def table12_league_eval(train_iters=16):
    """Tables 1-2: CSP-trained agent vs scripted bots in the FFA duel;
    FRAG reported (kills; no rocket splash => no suicides)."""
    from repro.configs import get_arch
    from repro.envs import make_env
    from repro.envs.scripted import duel_bot, random_bot
    from repro.eval import learned_policy_fn, play_episodes
    from repro.launch.train import run_league_training

    t0 = time.perf_counter()
    league, agents, _ = run_league_training(
        env_name="duel", arch="tleague-policy-s", periods=1,
        steps_per_period=train_iters, num_envs=16, unroll_len=16,
        verbose=False)
    cfg = get_arch("tleague-policy-s")
    env = make_env("duel")
    _, learner = agents["main"]
    me = learned_policy_fn(cfg, env.spec.num_actions, learner.params)
    rnd = random_bot(env.spec.num_actions)
    res = play_episodes(env, [me, duel_bot, duel_bot, rnd], episodes=5, seed=3)
    frags = res["frags"].mean(0)
    us = (time.perf_counter() - t0) * 1e6
    _emit("table12/duel_vs_bots", us,
          f"my_frag={frags[0]:.1f};bot_frag={frags[1:3].mean():.1f};"
          f"rand_frag={frags[3]:.1f}")


def fig4_winrate(train_iters=12):
    """Fig. 4: win-rate vs SimpleAgent (pommerman team mode, sp_pfsp 35/65
    mixture as §4.3). Short training — the full curve is examples/."""
    from repro.configs import get_arch
    from repro.envs import make_env
    from repro.envs.scripted import pommerman_simple_bot
    from repro.eval import learned_policy_fn, play_episodes, winrate_vs
    from repro.launch.train import run_league_training

    t0 = time.perf_counter()
    league, agents, _ = run_league_training(
        env_name="pommerman_lite", arch="tleague-policy-s", game_mgr="sp_pfsp",
        periods=1, steps_per_period=train_iters, num_envs=8, unroll_len=16,
        verbose=False)
    cfg = get_arch("tleague-policy-s")
    env = make_env("pommerman_lite")
    _, learner = agents["main"]
    me = learned_policy_fn(cfg, env.spec.num_actions, learner.params)
    res = play_episodes(env, [me, me, pommerman_simple_bot,
                              pommerman_simple_bot], episodes=6, seed=5)
    wr = winrate_vs(res["outcomes"])
    us = (time.perf_counter() - t0) * 1e6
    _emit("fig4/pommerman_vs_simple", us, f"winrate={wr:.2f}")


def kernels():
    from repro.kernels import flash_attention, reverse_discounted_scan, rmsnorm
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 4, 256, 64))
    kk = jax.random.normal(k, (1, 2, 256, 64))
    v = jax.random.normal(k, (1, 2, 256, 64))
    us = _time(lambda: jax.block_until_ready(
        flash_attention(q, kk, v, 0.125, True, 0, 0.0, 128, 128, True)))
    _emit("kernels/flash_attention_256", us, "interpret_mode")
    d = jax.random.normal(k, (32, 128))
    g = jax.random.uniform(k, (32, 128)) * 0.99
    us = _time(lambda: jax.block_until_ready(
        reverse_discounted_scan(d, g, interpret=True)))
    _emit("kernels/vtrace_scan_32x128", us, "interpret_mode")
    x = jax.random.normal(k, (512, 256))
    w = jnp.ones((256,))
    us = _time(lambda: jax.block_until_ready(rmsnorm(x, w, interpret=True)))
    _emit("kernels/rmsnorm_512x256", us, "interpret_mode")


BENCHES = ("table3_throughput", "table3_scaleup", "seed_infserver",
           "infserver_throughput", "kernels", "fig4_winrate",
           "table12_league_eval")


def main() -> None:
    """`python benchmarks/run.py [bench ...]` — no args runs everything."""
    chosen = sys.argv[1:] or list(BENCHES)
    unknown = [n for n in chosen if n not in BENCHES]
    assert not unknown, f"unknown benches {unknown}; pick from {BENCHES}"
    print("name,us_per_call,derived", flush=True)
    for name in chosen:
        globals()[name]()
    if sys.argv[1:]:
        return
    # roofline table (from dry-run artifacts, if present)
    try:
        from benchmarks import roofline
        recs = roofline.load_all()
        for r in recs:
            if "skip" in r:
                continue
            step_us = max(r["compute_s"], r["memory_s"],
                          r["collective_s"]) * 1e6
            _emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", step_us,
                  f"bottleneck={r['bottleneck']};useful={r['useful_frac']:.2f}")
    except Exception as e:
        print(f"# roofline skipped: {e}")


if __name__ == '__main__':
    main()
