"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  table3_throughput   — rfps / cfps / repeat ratio per env (paper Table 3)
  table3_scaleup      — rfps vs actor (env) count: the scale-up claim
  seed_infserver      — batched InfServer vs local batch-1 forwards (§3.2)
  infserver_throughput— central batched inference vs per-actor forwards at
                        64 simulated actors; writes BENCH_infserver.json
                        (the paper's Table-3-style serving claim as a
                        tracked number)
  table12_league_eval — league-trained agent vs scripted bots (Tables 1-2)
  fig4_winrate        — win-rate vs training iterations (Fig. 4), short run
  kernels             — Pallas kernel microbenches (interpret-mode on CPU:
                        correctness-path timing; TPU-target timing comes
                        from the roofline, see benchmarks/roofline.py)
  learner_throughput  — the learner hot path: a full seq-model V-trace
                        loss at train_4k scale (B=1, T=4096, sliding
                        window + softcap) timed fwd-only AND fwd+bwd
                        under the reference oracle vs the production
                        dispatch tier, with grad parity across the whole
                        param pytree asserted <=1e-4; plus env-scale
                        steps and host vs pipelined device feeding.
                        Writes BENCH_learner.json; supports
                        `--against FILE` (the CI regression gate)
  sharded_serving     — 1-device vs mesh-sharded InfServer forward
                        latency/throughput (parity asserted <=1e-4) and
                        in-process vs RPC seam overhead for the league
                        transport; writes BENCH_sharded.json
  param_plane         — the versioned param plane over RPC: full pull vs
                        hash-gated no-op pull vs changed-leaves delta
                        pull, chunked vs monolithic transfer, heartbeat
                        ping cost; asserts bit-exact parity across the
                        chunked path and >=50x no-op-vs-full; writes
                        BENCH_params.json. `--against FILE` re-runs and
                        fails on regression vs the stored record (CI).
  collector_throughput— the collector plane: served-path frames/sec vs
                        VectorEnv slot count (>=3x at 16 slots vs 1
                        asserted), ticket coalescing across two
                        collectors sharing one InfServer (batch
                        occupancy must improve), and the uniform
                        sampler's bit-identity to the pre-refactor
                        DataServer draw; writes BENCH_collector.json.
                        Supports `--against FILE` like param_plane.
  fault_recovery      — the robustness plane: task-lease re-issue
                        latency after an actor dies holding a match,
                        ModelPool pull availability while the primary
                        pool server is killed (failover to a read
                        replica), and actor-fleet frames/sec dip and
                        recovery across a 2-of-4 actor kill; writes
                        BENCH_fault.json. Supports `--against FILE`.

BENCH_*.json records are stamped with the git sha + UTC timestamp and
written atomically (tmp file + rename), so the bench trajectory files stay
comparable — and uncorrupted — across PRs.
"""
from __future__ import annotations

import datetime
import json
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

_REPO = pathlib.Path(__file__).resolve().parent.parent


def _emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _write_bench(path: pathlib.Path, record: dict) -> None:
    """Stamp and atomically write a BENCH_*.json trajectory record."""
    record = dict(record)
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO,
                             capture_output=True, text=True,
                             timeout=10).stdout.strip()
    except Exception:
        sha = ""
    record["git_sha"] = sha or "unknown"
    record["timestamp"] = datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds")
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(record, indent=2) + "\n")
    tmp.replace(path)


def _time(fn, iters=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
def table3_throughput():
    """Paper Table 3: rfps (actor producing) and cfps (learner consuming)."""
    from repro.actors import Actor
    from repro.configs import get_arch
    from repro.core import LeagueMgr
    from repro.envs import make_env
    from repro.learners import Learner, build_env_train_step
    from repro.models import init_params
    from repro.optim import adamw

    for env_name, num_envs, unroll in [("rps", 32, 8),
                                       ("pommerman_lite", 8, 16),
                                       ("duel", 8, 16)]:
        cfg = get_arch("tleague-policy-s")
        env = make_env(env_name)
        params = init_params(jax.random.PRNGKey(0), cfg)
        league = LeagueMgr()
        league.add_learning_agent("main", params)
        actor = Actor(env, cfg, league, num_envs=num_envs, unroll_len=unroll)
        opt = adamw(3e-4)
        step = build_env_train_step(cfg, env.spec.num_actions, opt)
        learner = Learner(league, step, opt, params)
        actor.run_segment()  # compile
        t0 = time.perf_counter()
        n_seg = 4
        for _ in range(n_seg):
            traj, _ = actor.run_segment()
            learner.data_server.put(traj)
            learner.learn()
        dt = time.perf_counter() - t0
        frames = n_seg * num_envs * unroll
        tp = learner.data_server.throughput()
        us = dt / n_seg * 1e6
        _emit(f"table3/{env_name}", us,
              f"rfps={frames/dt:.0f};cfps={tp['cfps']:.0f};"
              f"repeat={tp['repeat_ratio']:.2f}")


def table3_scaleup():
    """rfps vs parallel-env count (the paper's actor scale-up axis)."""
    from repro.actors.rollout import build_rollout
    from repro.configs import get_arch
    from repro.envs import make_env
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    env = make_env("rps")
    params = init_params(jax.random.PRNGKey(0), cfg)
    base_rfps = None
    for n in (4, 16, 64):
        rollout, init_carry = build_rollout(env, cfg, num_envs=n, unroll_len=8)
        carry = init_carry(jax.random.PRNGKey(1))
        r = jax.random.PRNGKey(2)
        jax.block_until_ready(rollout(params, params, carry, r)[1]["actions"])
        t0 = time.perf_counter()
        iters = 3
        for i in range(iters):
            carry, traj, _ = rollout(params, params, carry,
                                     jax.random.fold_in(r, i))
        jax.block_until_ready(traj["actions"])
        dt = (time.perf_counter() - t0) / iters
        rfps = n * 8 / dt
        base_rfps = base_rfps or rfps
        _emit(f"table3_scaleup/envs{n}", dt * 1e6,
              f"rfps={rfps:.0f};scaleup_x={rfps/base_rfps:.2f}")


def seed_infserver():
    """SEED claim (§3.2): batched central inference beats batch-1 locals."""
    from repro.configs import get_arch
    from repro.infserver import InfServer
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = InfServer(cfg, 6, params, max_batch=64)
    obs = np.zeros((1, 26), np.int32)
    server.get(server.submit(obs))  # compile batch-1 path
    us_local = _time(lambda: server.get(server.submit(obs)), iters=16)

    def batched():
        tickets = [server.submit(obs) for _ in range(64)]
        for t in tickets:
            server.get(t)

    batched()  # compile batch-64 path
    us_batch = _time(batched, iters=4) / 64
    _emit("seed_infserver/local_b1", us_local, "per_request")
    _emit("seed_infserver/batched64", us_batch,
          f"per_request;speedup_x={us_local/us_batch:.1f}")


def infserver_throughput(num_actors: int = 64, out_path: str | None = None):
    """Central batched inference vs per-actor batch-1 forwards with
    `num_actors` simulated clients (§3.2 / Table 3 serving claim). Writes
    the result to BENCH_infserver.json so the >=2x speedup is tracked."""
    from repro.actors.policy import make_obs_policy
    from repro.configs import get_arch
    from repro.infserver import InfServer
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    num_actions, obs_len = 6, 26
    obs1 = np.zeros((1, obs_len), np.int32)

    # baseline: every simulated actor runs its own batch-1 forward
    policy = make_obs_policy(cfg, num_actions)
    local_act = jax.jit(policy.act)
    rng = jax.random.PRNGKey(1)
    jax.block_until_ready(local_act(params, rng, jnp.asarray(obs1)))

    def per_actor_round():
        for i in range(num_actors):
            a, _, _ = local_act(params, jax.random.fold_in(rng, i),
                                jnp.asarray(obs1))
        jax.block_until_ready(a)

    us_local = _time(per_actor_round, iters=4) / num_actors

    # central: the same num_actors requests ride one continuous batch
    server = InfServer(cfg, num_actions, params, max_batch=num_actors)

    def central_round():
        tickets = [server.submit(obs1) for _ in range(num_actors)]
        for t in tickets:
            server.get(t)

    central_round()  # compile the batched path
    us_central = _time(central_round, iters=4) / num_actors

    speedup = us_local / us_central
    stats = server.stats()
    record = {
        "num_actors": num_actors,
        "per_actor_us_per_request": round(us_local, 2),
        "central_batched_us_per_request": round(us_central, 2),
        "speedup_x": round(speedup, 2),
        "server_occupancy": round(stats["occupancy"], 4),
        "server_mean_batch_rows": stats["mean_batch_rows"],
        "server_mean_batch_latency_ms": round(
            stats["mean_batch_latency_ms"], 3),
        "arch": "tleague-policy-s",
    }
    path = pathlib.Path(out_path) if out_path else _REPO / "BENCH_infserver.json"
    _write_bench(path, record)
    _emit(f"infserver/per_actor{num_actors}", us_local, "per_request")
    _emit(f"infserver/central{num_actors}", us_central,
          f"per_request;speedup_x={speedup:.1f};wrote={path.name}")
    return record


def table12_league_eval(train_iters=16):
    """Tables 1-2: CSP-trained agent vs scripted bots in the FFA duel;
    FRAG reported (kills; no rocket splash => no suicides)."""
    from repro.configs import get_arch
    from repro.envs import make_env
    from repro.envs.scripted import duel_bot, random_bot
    from repro.eval import learned_policy_fn, play_episodes
    from repro.launch.train import run_league_training

    t0 = time.perf_counter()
    league, agents, _ = run_league_training(
        env_name="duel", arch="tleague-policy-s", periods=1,
        steps_per_period=train_iters, num_envs=16, unroll_len=16,
        verbose=False)
    cfg = get_arch("tleague-policy-s")
    env = make_env("duel")
    _, learner = agents["main"]
    me = learned_policy_fn(cfg, env.spec.num_actions, learner.params)
    rnd = random_bot(env.spec.num_actions)
    res = play_episodes(env, [me, duel_bot, duel_bot, rnd], episodes=5, seed=3)
    frags = res["frags"].mean(0)
    us = (time.perf_counter() - t0) * 1e6
    _emit("table12/duel_vs_bots", us,
          f"my_frag={frags[0]:.1f};bot_frag={frags[1:3].mean():.1f};"
          f"rand_frag={frags[3]:.1f}")


def fig4_winrate(train_iters=12):
    """Fig. 4: win-rate vs SimpleAgent (pommerman team mode, sp_pfsp 35/65
    mixture as §4.3). Short training — the full curve is examples/."""
    from repro.configs import get_arch
    from repro.envs import make_env
    from repro.envs.scripted import pommerman_simple_bot
    from repro.eval import learned_policy_fn, play_episodes, winrate_vs
    from repro.launch.train import run_league_training

    t0 = time.perf_counter()
    league, agents, _ = run_league_training(
        env_name="pommerman_lite", arch="tleague-policy-s", game_mgr="sp_pfsp",
        periods=1, steps_per_period=train_iters, num_envs=8, unroll_len=16,
        verbose=False)
    cfg = get_arch("tleague-policy-s")
    env = make_env("pommerman_lite")
    _, learner = agents["main"]
    me = learned_policy_fn(cfg, env.spec.num_actions, learner.params)
    res = play_episodes(env, [me, me, pommerman_simple_bot,
                              pommerman_simple_bot], episodes=6, seed=5)
    wr = winrate_vs(res["outcomes"])
    us = (time.perf_counter() - t0) * 1e6
    _emit("fig4/pommerman_vs_simple", us, f"winrate={wr:.2f}")


def learner_throughput(out_path: str | None = None, iters: int = 8,
                       against: str | None = None):
    """Learner hot-path benchmark (ISSUE 2 + ISSUE 8 acceptance).

    Three sections, all feeding one BENCH_learner.json record:

      * parity     — dispatch(interpret) vs reference across all three
                     kernel families, asserted <=1e-4 (the Pallas kernels
                     are bit-audited elsewhere; this is the integration
                     check).
      * seq 4k     — the headline: a full seq-model V-trace loss
                     (tleague-policy-s, sliding_window=512, softcap, B=1,
                     T=4096) timed fwd-only and fwd+bwd under
                     force('reference') (full-T^2 oracle attention) vs
                     force('auto') (the production tier: windowed chunked
                     attention on CPU, compiled Pallas flash fwd+bwd on
                     TPU/GPU). Gradient parity between the two modes is
                     asserted <=1e-4 across the whole param-grad pytree —
                     the backward path is in the measured + audited loop,
                     not just the forward. `fused_speedup_x` is the
                     fwd+bwd ratio.
      * env + feed — the legacy env-scale step timing (now `env_*`
                     fields) and host vs double-buffered feeding.

    With `against`, re-runs and fails on regression vs the stored record
    (the CI gate; see `_check_against`).
    """
    import dataclasses

    from repro.configs import get_arch
    from repro.kernels import dispatch
    from repro.learners import DataServer, build_env_train_step
    from repro.models import forward_train, init_params
    from repro.optim import adamw
    from repro.rl.returns import gae, lambda_return
    from repro.rl.vtrace import vtrace
    from repro.rl.vtrace_loss import VTraceConfig, vtrace_loss

    cfg = get_arch("tleague-policy-s")
    num_actions, obs_len = 6, 26
    B, T = 32, 16
    rng = np.random.default_rng(0)

    def synth_traj():
        return {
            "obs": rng.integers(0, 16, (B, T, obs_len)).astype(np.int32),
            "actions": rng.integers(0, num_actions, (B, T)).astype(np.int32),
            "behavior_logp": (-np.abs(rng.normal(size=(B, T)))
                              ).astype(np.float32),
            "behavior_values": rng.normal(size=(B, T)).astype(np.float32),
            "rewards": rng.normal(size=(B, T)).astype(np.float32),
            "done": rng.random((B, T)) < 0.05,
            "bootstrap_value": rng.normal(size=(B,)).astype(np.float32),
        }

    # -- parity: every kernel family, dispatch(interpret) vs reference ------
    tr = synth_traj()
    args = (jnp.asarray(tr["rewards"]), jnp.asarray(tr["behavior_values"]),
            0.99 * (1.0 - jnp.asarray(tr["done"], jnp.float32)),
            jnp.asarray(tr["bootstrap_value"]))
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (2, 4, 64, 32))
    kv = jax.random.normal(jax.random.fold_in(k, 1), (2, 2, 64, 32))
    xw = jax.random.normal(jax.random.fold_in(k, 2), (64, 128)), jnp.ones((128,))
    outs = {}
    for m in ("reference", "interpret"):
        with dispatch.force(m):
            outs[m] = [gae(*args)[0], lambda_return(*args),
                       vtrace(jnp.asarray(tr["behavior_logp"]),
                              jnp.asarray(tr["behavior_logp"]) * 0.9,
                              *args)[0],
                       dispatch.attention(q, kv, kv, scale=0.18, causal=True,
                                          window=16, cap=30.0),
                       dispatch.rmsnorm(*xw)]
    parity = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(outs["reference"], outs["interpret"]))
    assert parity <= 1e-4, f"kernel/reference parity {parity} > 1e-4"

    # -- seq 4k: full train_4k-scale loss, fwd-only and fwd+bwd -------------
    # fp32 compute so the <=1e-4 grad-parity bar is meaningful; max_position
    # bumped past T=4096; all-local layers exercise window+softcap (the
    # flash kernel's hardest masking combo) end to end.
    cfg4 = dataclasses.replace(
        get_arch("tleague-policy-s"), sliding_window=512,
        attn_logit_softcap=30.0, layer_pattern=("local",),
        compute_dtype="float32", max_position=8192)
    B4, T4 = 1, 4096
    hp4 = VTraceConfig()
    batch4 = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg4.vocab_size, (B4, T4)).astype(np.int32)),
        "actions": jnp.asarray(
            rng.integers(0, cfg4.vocab_size, (B4, T4)).astype(np.int32)),
        "behavior_logp": jnp.asarray(
            (-np.abs(rng.normal(size=(B4, T4)))).astype(np.float32)),
        "behavior_values": jnp.asarray(
            rng.normal(size=(B4, T4)).astype(np.float32)),
        "rewards": jnp.asarray(rng.normal(size=(B4, T4)).astype(np.float32)),
        "discounts": jnp.asarray(
            (hp4.gamma * (rng.random((B4, T4)) >= 0.01)).astype(np.float32)),
        "bootstrap_value": jnp.asarray(
            rng.normal(size=(B4,)).astype(np.float32)),
    }

    def make_seq_loss():
        # Fresh function object per dispatch mode: jax.jit's compilation
        # cache is keyed on the wrapped function (+ avals), NOT on the
        # dispatch mode read at trace time — re-jitting the same object
        # under a different force() would silently reuse the first mode's
        # executable (see repro.kernels.dispatch docstring).
        def seq_loss(p, b):
            # mirrors build_seq_train_step's loss_fn: forward_train ->
            # vtrace (rl losses route v-trace through dispatch.reverse_scan,
            # so the fused scan kernel is inside this timing at full 4k
            # unroll). q_chunk=256: each query chunk attends a 256+window
            # key slice — the production setting for window=512 locals.
            logits, values, aux = forward_train(
                p, cfg4, {"tokens": b["tokens"]}, q_chunk=256, remat=True)
            tfields = {k: b[k] for k in ("actions", "behavior_logp",
                                         "behavior_values", "rewards",
                                         "discounts", "bootstrap_value")}
            lv, _ = vtrace_loss(logits, values, tfields, hp4)
            return lv + aux
        return seq_loss

    params4 = init_params(jax.random.PRNGKey(1), cfg4)
    seq_iters = max(2, iters // 4)
    seq_us, grads_by_mode = {}, {}
    for mode_name in ("reference", "auto"):
        with dispatch.force(mode_name):
            seq_loss = make_seq_loss()
            fwd = jax.jit(seq_loss)
            fwdbwd = jax.jit(jax.grad(seq_loss))
            for tag, fn in (("fwd", fwd), ("fwdbwd", fwdbwd)):
                out = fn(params4, batch4)                      # compile
                jax.block_until_ready(out)
                t0 = time.perf_counter()
                for _ in range(seq_iters):
                    out = fn(params4, batch4)
                jax.block_until_ready(out)
                seq_us[f"{tag}_{mode_name}"] = (
                    (time.perf_counter() - t0) / seq_iters * 1e6)
            grads_by_mode[mode_name] = fwdbwd(params4, batch4)
    gref, gauto = grads_by_mode["reference"], grads_by_mode["auto"]
    grad_parity = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(gref), jax.tree.leaves(gauto)))
    assert grad_parity <= 1e-4, \
        f"seq-4k grad parity {grad_parity} > 1e-4 (reference vs auto)"
    seq_speedup = seq_us["fwdbwd_reference"] / seq_us["fwdbwd_auto"]
    seq_fwd_speedup = seq_us["fwd_reference"] / seq_us["fwd_auto"]
    _emit("learner/seq4k_fwd_reference", seq_us["fwd_reference"], "us_per_call")
    _emit("learner/seq4k_fwd_fused", seq_us["fwd_auto"],
          f"us_per_call;speedup_x={seq_fwd_speedup:.2f}")
    _emit("learner/seq4k_fwdbwd_reference", seq_us["fwdbwd_reference"],
          "us_per_call")
    _emit("learner/seq4k_fwdbwd_fused", seq_us["fwdbwd_auto"],
          f"us_per_call;speedup_x={seq_speedup:.2f};"
          f"grad_parity={grad_parity:.2e}")

    # -- env-scale train-step timing: reference vs fused dispatch -----------
    opt = adamw(3e-4)
    step_us = {}
    for mode_name in ("reference", "auto"):
        with dispatch.force(mode_name):
            step = build_env_train_step(cfg, num_actions, opt)
            params = init_params(jax.random.PRNGKey(0), cfg)
            opt_state = opt.init(params)
            trajs = [synth_traj() for _ in range(iters)]
            params, opt_state, m = step(params, opt_state, trajs[0])  # compile
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for tr_i in trajs:
                params, opt_state, m = step(params, opt_state, tr_i)
            jax.block_until_ready(m["loss"])
            step_us[mode_name] = (time.perf_counter() - t0) / iters * 1e6
    speedup = step_us["reference"] / step_us["auto"]
    _emit("learner/step_reference", step_us["reference"], "us_per_step")
    _emit("learner/step_fused", step_us["auto"],
          f"us_per_step;speedup_x={speedup:.2f}")

    # -- feeding: host sample vs double-buffered sample_to_device -----------
    opt2 = adamw(3e-4)
    step = build_env_train_step(cfg, num_actions, opt2)
    feed_fps = {}
    for name, use_device in (("host", False), ("prefetch", True)):
        ds = DataServer(capacity_frames=4 * B * T, prefetch=use_device)
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt2.init(params)
        ds.put(synth_traj())
        batch = ds.sample_to_device() if use_device else ds.sample()
        params, opt_state, m = step(params, opt_state, batch)  # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(iters):
            ds.put(synth_traj())
            batch = ds.sample_to_device() if use_device else ds.sample()
            params, opt_state, m = step(params, opt_state, batch)
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        feed_fps[name] = iters * B * T / dt
        extra = ""
        if use_device:
            tp = ds.throughput()
            extra = (f";prefetch_hits={tp['prefetch_hits']}"
                     f";prefetch_misses={tp['prefetch_misses']}")
        _emit(f"learner/feed_{name}", dt / iters * 1e6,
              f"frames_per_s={feed_fps[name]:.0f}{extra}")

    record = {
        "backend": jax.default_backend(),
        "arch": "tleague-policy-s",
        "parity_max_abs_err": parity,
        # headline: seq-model V-trace loss at train_4k scale (B=1, T=4096,
        # window=512, softcap), reference oracle vs production dispatch
        "seq_len": T4,
        "seq_batch_rows": B4,
        "seq_fwd_reference_us": round(seq_us["fwd_reference"], 2),
        "seq_fwd_fused_us": round(seq_us["fwd_auto"], 2),
        "seq_fwd_speedup_x": round(seq_fwd_speedup, 3),
        "seq_fwdbwd_reference_us": round(seq_us["fwdbwd_reference"], 2),
        "seq_fwdbwd_fused_us": round(seq_us["fwdbwd_auto"], 2),
        "fused_speedup_x": round(seq_speedup, 3),
        "seq_grad_parity_max_abs_err": grad_parity,
        # legacy env-scale step timing (B=32, T=16 obs-token policy)
        "env_batch_rows": B,
        "env_unroll_len": T,
        "env_reference_us_per_step": round(step_us["reference"], 2),
        "env_fused_us_per_step": round(step_us["auto"], 2),
        "env_fused_speedup_x": round(speedup, 3),
        "host_feed_frames_per_s": round(feed_fps["host"], 1),
        "prefetch_feed_frames_per_s": round(feed_fps["prefetch"], 1),
    }
    path = pathlib.Path(out_path) if out_path else _REPO / "BENCH_learner.json"
    prior = json.loads(pathlib.Path(against).read_text()) if against else None
    _write_bench(path, record)
    _emit("learner/bench_written", 0.0, f"wrote={path.name}")
    if prior is not None:
        _check_against(record, prior, against,
                       floors={"fused_speedup_x": (1.5, 0.5),
                               "seq_fwd_speedup_x": (1.5, 0.5)})
    return record


def league_throughput(out_path: str | None = None, seconds: float = 10.0):
    """ISSUE 3 acceptance: the event-driven league runtime vs the legacy
    lockstep loop at matched counts (2 roles x 2 actors = 4 actors, 2
    learners) on one host, plus async freeze latency and a seeded --sync
    bit-determinism check. Writes BENCH_league.json.

    Both schedules drive IDENTICAL prewarmed components (same build_runtime
    wiring, jits compiled before the clock starts): the sync baseline runs
    the nested actor->learner loop on the main thread, the async side runs
    the same workers on their own threads — the measured delta is purely
    the schedule."""
    from repro.core import FreezeGate
    from repro.league import LeagueSpec, RoleSpec, build_runtime
    from repro.launch.train import run_league_training

    # the paper's Pommerman setting (§4.3): env stepping heavy enough that
    # the schedule, not a single fused op, decides throughput
    env_name, num_envs, unroll = "pommerman_lite", 8, 16
    actors_per_role, n_freeze_steps = 2, 2

    def mk_spec():
        return LeagueSpec(roles=(
            RoleSpec(name="main", role="main", num_actors=actors_per_role,
                     gate=FreezeGate(step_gate=n_freeze_steps)),
            RoleSpec(name="exploiter:0", role="minimax_exploiter",
                     target="main", num_actors=actors_per_role,
                     gate=FreezeGate(step_gate=n_freeze_steps)),
        ))

    def build_prewarmed():
        rt = build_runtime(mk_spec(), env_name=env_name, num_envs=num_envs,
                           unroll_len=unroll, seed=0)
        for role in rt.roles:            # compile every jit off the clock
            for w in role.actors:
                traj, _ = w.actor.run_segment()
                role.data_server.put(traj)
            role.learner.learner.learn(num_steps=1)
        return rt

    def frames(rt):
        return sum(w.actor.frames_produced
                   for role in rt.roles for w in role.actors)

    # -- sync baseline: the lockstep nested loop, main thread ----------------
    rt_sync = build_prewarmed()
    f0, t0 = frames(rt_sync), time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        for role in rt_sync.roles:
            for w in role.actors:
                traj, _ = w.actor.run_segment()
                role.data_server.put(traj)
            role.learner.learner.learn(num_steps=len(role.actors))
    dt_sync = time.perf_counter() - t0
    fps_sync = (frames(rt_sync) - f0) / dt_sync

    # -- async: same components, event-driven ---------------------------------
    rt_async = build_prewarmed()
    f0 = frames(rt_async)
    report = rt_async.run(max_seconds=seconds)
    fps_async = (frames(rt_async) - f0) / report["wall_s"]
    speedup = fps_async / fps_sync

    # -- seeded --sync bit-determinism ---------------------------------------
    def sync_run():
        league, _, history = run_league_training(
            env_name="rps", num_envs=4, unroll_len=8, periods=1,
            steps_per_period=3, league_spec=mk_spec(), seed=11,
            verbose=False)
        state = league.league_state()
        state.pop("wall_s", None)
        return [r.get("loss") for r in history], state
    la, sa = sync_run()
    lb, sb = sync_run()
    deterministic = (la == lb and sa == sb)   # float == float: bitwise
    assert deterministic, "seeded --sync run is not bit-deterministic"

    record = {
        "env": env_name,
        "arch": "tleague-policy-s",
        "num_envs": num_envs,
        "unroll_len": unroll,
        "roles": 2,
        "actors": 2 * actors_per_role,
        "learners": 2,
        "measure_seconds": seconds,
        "sync_frames_per_s": round(fps_sync, 1),
        "async_frames_per_s": round(fps_async, 1),
        "async_speedup_x": round(speedup, 3),
        "async_freezes": report["league"]["num_freezes"],
        "freeze_latency_s_mean": report["freeze_latency_s_mean"],
        "freeze_latency_s_max": report["freeze_latency_s_max"],
        "async_clean_shutdown": report["clean_shutdown"],
        "sync_bit_deterministic": deterministic,
        "backend": jax.default_backend(),
    }
    path = pathlib.Path(out_path) if out_path else _REPO / "BENCH_league.json"
    _write_bench(path, record)
    _emit("league/sync_lockstep", dt_sync * 1e6,
          f"frames_per_s={fps_sync:.0f}")
    _emit("league/async_runtime", report["wall_s"] * 1e6,
          f"frames_per_s={fps_async:.0f};speedup_x={speedup:.2f};"
          f"freeze_latency_ms={1e3 * (report['freeze_latency_s_mean'] or 0):.0f};"
          f"wrote={path.name}")
    return record


def sharded_serving(out_path: str | None = None, num_actors: int = 32):
    """ISSUE 4 acceptance: (a) the InfServer's grouped forward on one
    device vs mesh-sharded over the local ('data','model') mesh — same
    seed, parity asserted <=1e-4 — and (b) the cost of making the league
    seams process boundaries: in-process calls vs msgpack-RPC over
    loopback for ModelPool.pull, LeagueMgr.request_task and the InfServer
    submit/flush/get round trip. Writes BENCH_sharded.json.

    On a 1-device host `make_local_mesh` collapses to (1, 1) and the
    sharded numbers measure pure mesh-placement overhead; on a real pod
    the same harness times the TP+DP layout (`make_production_mesh`)."""
    from repro.configs import get_arch
    from repro.core import LeagueMgr, ModelKey
    from repro.distributed import transport as tp
    from repro.infserver import InfServer
    from repro.launch.mesh import make_local_mesh
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    num_actions, obs_len = 6, 26
    obs1 = np.zeros((1, obs_len), np.int32)
    mesh = make_local_mesh()

    # -- (a) single-device vs mesh-sharded grouped forward -------------------
    def serve_round(server):
        tickets = [server.submit(obs1, model=("theta" if i % 2 == 0 else "phi"))
                   for i in range(num_actors)]
        server.flush()
        return [server.get(t) for t in tickets]

    outs, us = {}, {}
    for name, m in (("single", None), ("sharded", mesh)):
        server = InfServer(cfg, num_actions, seed=11, max_batch=2 * num_actors,
                           mesh=m)
        server.register_model("theta", params)
        server.register_model("phi", params)
        outs[name] = serve_round(server)       # also compiles
        us[name] = _time(lambda s=server: serve_round(s), iters=4) / num_actors
    parity = max(float(np.max(np.abs(np.asarray(a, np.float64)
                                     - np.asarray(b, np.float64))))
                 for ra, rb in zip(outs["single"], outs["sharded"])
                 for a, b in zip(ra, rb))
    assert parity <= 1e-4, f"sharded/single forward parity {parity} > 1e-4"
    _emit("sharded/forward_single", us["single"], "per_request")
    _emit("sharded/forward_sharded", us["sharded"],
          f"per_request;parity={parity:.2e};"
          f"mesh={'x'.join(map(str, mesh.devices.shape))}")

    # -- (b) in-process vs RPC seam overhead ---------------------------------
    league = LeagueMgr()
    league.add_learning_agent("main", params)
    inf = InfServer(cfg, num_actions, params, max_batch=8)
    inf.get(inf.submit(obs1))                   # compile off the clock
    srv = tp.serve_league(league, inf)
    lg = tp.LeagueMgrClient(srv.address)
    ic = tp.InfServerClient(tp.RpcClient(srv.address))
    key = ModelKey("main", 0)
    try:
        seams = {
            "pool_pull": (lambda: league.model_pool.pull(key),
                          lambda: lg.model_pool.pull(key)),
            "request_task": (lambda: league.request_task("main"),
                             lambda: lg.request_task("main")),
            "inf_round": (lambda: inf.get(inf.submit(obs1)),
                          lambda: ic.get(ic.submit(obs1))),
        }
        rpc_overhead = {}
        for name, (local_fn, rpc_fn) in seams.items():
            us_local = _time(local_fn, iters=16)
            us_rpc = _time(rpc_fn, iters=16)
            rpc_overhead[name] = {
                "inproc_us": round(us_local, 2), "rpc_us": round(us_rpc, 2),
                "overhead_x": round(us_rpc / max(us_local, 1e-9), 2),
            }
            _emit(f"sharded/rpc_{name}", us_rpc,
                  f"inproc_us={us_local:.1f};"
                  f"overhead_x={rpc_overhead[name]['overhead_x']}")
    finally:
        srv.close()

    record = {
        "backend": jax.default_backend(),
        "num_devices": len(jax.devices()),
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "num_actors": num_actors,
        "arch": "tleague-policy-s",
        "codec": tp.CODEC,
        "single_us_per_request": round(us["single"], 2),
        "sharded_us_per_request": round(us["sharded"], 2),
        "sharded_speedup_x": round(us["single"] / max(us["sharded"], 1e-9), 3),
        "parity_max_abs_err": parity,
        "rpc_seams": rpc_overhead,
    }
    path = pathlib.Path(out_path) if out_path else _REPO / "BENCH_sharded.json"
    _write_bench(path, record)
    _emit("sharded/bench_written", 0.0, f"wrote={path.name}")
    return record


def param_plane(out_path: str | None = None, against: str | None = None,
                model_mb: int = 64):
    """ISSUE 5 acceptance: what a `pool_pull` costs over RPC under the
    versioned param plane. One synthetic ~`model_mb` MB pytree is hosted
    in a ModelPool behind the transport; measured per axis:

      * full pull        — the pre-param-plane contract (ship everything)
      * no-op pull       — `pull_if_changed` at the current version: one
                           NotModified tag (the >=50x headline)
      * delta pull       — one small leaf changed: only it crosses
      * chunked vs monolithic — the same full pull with streaming
                           transfer disabled (one giant msgpack frame)
      * heartbeat ping   — the liveness channel's per-probe cost

    Pulled params are asserted BIT-EXACT against the pool copy across
    the chunked path (dtype + bytes). With `against`, the fresh record
    is compared to a stored BENCH_params.json and a regression (ratio
    floors below) fails the run — the CI mode."""
    from repro.core.model_pool import ModelPool
    from repro.core.types import ModelKey
    from repro.distributed import transport as tp
    from repro.distributed.heartbeat import Heartbeat

    # read the reference BEFORE the run overwrites it (the CI invocation
    # passes the same BENCH_params.json path this bench writes)
    prior = (json.loads(pathlib.Path(against).read_text())
             if against else None)
    rng = np.random.default_rng(7)
    n_layers = max(1, model_mb // 4)
    params = {f"layer{i}": {"w": rng.normal(size=(1024, 1024)).astype(np.float32),
                            "b": rng.normal(size=(1024,)).astype(np.float32)}
              for i in range(n_layers)}
    nbytes = sum(a.nbytes for lyr in params.values() for a in lyr.values())

    pool = ModelPool(snapshot_on_pull=True)
    key = ModelKey("bench", 0)
    pool.push(key, params)
    hb = Heartbeat().start_beating(0.5)
    srv = tp.RpcServer({"pool": pool, "ctrl": hb}).start()
    raw = tp.RpcClient(srv.address)
    try:
        # -- full pull, chunked (default) vs monolithic ----------------------
        pulled = raw.call("pool.pull", key)
        for lyr in params:                       # bit-exact across chunks
            for name, truth in params[lyr].items():
                got = pulled[lyr][name]
                assert got.dtype == truth.dtype and np.array_equal(got, truth), \
                    f"chunked pull not bit-exact at {lyr}/{name}"
        us_full = _time(lambda: raw.call("pool.pull", key), iters=5)
        with tp.chunking(threshold=1 << 62):     # never stream: one big frame
            us_mono = _time(lambda: raw.call("pool.pull", key), iters=5)

        # -- hash-gated no-op pull ------------------------------------------
        v = pool.version(key)
        us_noop = _time(lambda: raw.call("pool.pull_if_changed", key, v),
                        iters=16)

        # -- delta pull: one small leaf changes -----------------------------
        params2 = dict(params, layer0={"w": params["layer0"]["w"],
                                       "b": params["layer0"]["b"] + 1.0})
        pool.push(key, params2)
        delta = raw.call("pool.pull_if_changed", key, v)
        assert not delta.full and list(delta.leaves), "expected a leaf delta"
        rebuilt = tp.apply_delta(pulled, delta.leaves)
        for lyr in params2:
            for name, truth in params2[lyr].items():
                assert np.array_equal(rebuilt[lyr][name], truth), \
                    f"delta reconstruction not bit-exact at {lyr}/{name}"
        us_delta = _time(lambda: raw.call("pool.pull_if_changed", key, v),
                         iters=16)

        # -- heartbeat ------------------------------------------------------
        us_ping = _time(lambda: raw.call("ctrl.ping"), iters=32)
    finally:
        raw.close()
        srv.close()
        hb.stop_beating()

    noop_x = us_full / max(us_noop, 1e-9)
    delta_x = us_full / max(us_delta, 1e-9)
    chunk_x = us_mono / max(us_full, 1e-9)
    assert noop_x >= 50, (
        f"hash-gated no-op pull only {noop_x:.1f}x cheaper than full (<50x)")
    record = {
        "model_mb": round(nbytes / 2**20, 1),
        "codec": tp.CODEC,
        "full_pull_ms": round(us_full / 1e3, 3),
        "full_pull_monolithic_ms": round(us_mono / 1e3, 3),
        "noop_pull_us": round(us_noop, 2),
        "delta_pull_us": round(us_delta, 2),
        "heartbeat_ping_us": round(us_ping, 2),
        "noop_speedup_x": round(noop_x, 1),
        "delta_speedup_x": round(delta_x, 1),
        "chunked_speedup_x": round(chunk_x, 3),
        "pull_parity_bit_exact": True,
        "pool_pull_stats": dict(pool.pull_stats),
    }
    path = pathlib.Path(out_path) if out_path else _REPO / "BENCH_params.json"
    _write_bench(path, record)
    _emit("params/full_pull", us_full, f"model_mb={record['model_mb']}")
    _emit("params/full_pull_monolithic", us_mono,
          f"chunked_speedup_x={chunk_x:.2f}")
    _emit("params/noop_pull", us_noop, f"speedup_x={noop_x:.0f}")
    _emit("params/delta_pull", us_delta, f"speedup_x={delta_x:.0f}")
    _emit("params/heartbeat_ping", us_ping, f"wrote={path.name}")
    if prior is not None:
        _check_against(record, prior, against,
                       floors={"noop_speedup_x": (50.0, 0.4),
                               "delta_speedup_x": (5.0, 0.4)})
    return record


def _check_against(record: dict, prior: dict, label: str,
                   floors: dict) -> None:
    """Regression gate: each metric must clear its absolute floor AND a
    fraction of the stored record's value (runner classes differ, so the
    relative bar is loose). Raises AssertionError on regression."""
    failures = []
    for metric, (absolute, rel) in floors.items():
        bar = max(absolute, rel * float(prior.get(metric, 0.0)))
        if float(record[metric]) < bar:
            failures.append(f"{metric}: {record[metric]} < {bar:.1f} "
                            f"(prior {prior.get(metric)})")
    assert not failures, f"bench regression vs {label}: " + "; ".join(failures)
    _emit("params/regression_check", 0.0, f"ok_vs={label}")


def collector_throughput(out_path: str | None = None,
                         against: str | None = None):
    """ISSUE 6 acceptance: the collector plane's three headline numbers.

      * slot scaling   — served-path frames/sec at 1 / 4 / 16 VectorEnv
                         slots against one InfServer; 16 slots must be
                         >=3x the frames/sec of 1 slot (batched central
                         inference amortizes the forward, §3.2)
      * coalescing     — two collectors sharing one server, driven
                         interleaved vs back-to-back: the shared ticket
                         stream must produce denser batches (higher
                         mean rows per batch, fewer batches, same rows)
      * uniform parity — the pluggable `uniform` sampler draws the
                         bit-identical slot stream the pre-refactor
                         `DataServer._sample_idx` drew

    Writes BENCH_collector.json; with `against`, compares to the stored
    record and fails on regression (the CI mode)."""
    from repro.actors import build_served_rollout
    from repro.actors.collector import ServedCollector, collect_interleaved
    from repro.configs import get_arch
    from repro.envs import JaxVectorEnv, make_env
    from repro.infserver import InfServer
    from repro.learners import DataServer
    from repro.models import init_params

    prior = (json.loads(pathlib.Path(against).read_text())
             if against else None)
    env = make_env("rps")
    cfg = get_arch("tleague-policy-s")
    theta = init_params(jax.random.PRNGKey(0), cfg)
    phi = init_params(jax.random.PRNGKey(1), cfg)
    T = 16

    def fresh_server():
        srv = InfServer(cfg, env.spec.num_actions, max_batch=256)
        srv.register_model("theta", theta)
        srv.register_model("phi", phi)
        return srv

    # -- served-path frames/sec vs slot count ------------------------------
    fps = {}
    for E in (1, 4, 16):
        server = fresh_server()
        rollout, init_carry = build_served_rollout(env, num_envs=E,
                                                   unroll_len=T)
        carry = init_carry(jax.random.PRNGKey(2))
        carry, _, _ = rollout(server, "theta", "phi", carry,
                              jax.random.PRNGKey(3))   # compile
        n_seg = 4
        t0 = time.perf_counter()
        for i in range(n_seg):
            carry, traj, _ = rollout(server, "theta", "phi", carry,
                                     jax.random.PRNGKey(4 + i))
        dt = time.perf_counter() - t0
        frames = n_seg * traj["obs"].shape[0] * T      # learner rows * T
        fps[E] = frames / dt
        _emit(f"collector/served_slots{E}", dt / n_seg * 1e6,
              f"fps={fps[E]:.0f}")
    scaling = fps[16] / max(fps[1], 1e-9)
    assert scaling >= 3.0, (
        f"16 slots only {scaling:.2f}x the frames/sec of 1 slot (<3x)")

    # -- ticket coalescing: 2 collectors, one server -----------------------
    E_c, n_cols = 8, 2

    def run(interleave):
        srv = fresh_server()
        cols = [ServedCollector(JaxVectorEnv(env, E_c, jit=True),
                                unroll_len=T) for _ in range(n_cols)]
        jobs = [("theta", "phi",
                 cols[i].init_carry(jax.random.PRNGKey(10 + i)),
                 jax.random.PRNGKey(20 + i)) for i in range(n_cols)]
        if interleave:
            collect_interleaved(cols, srv, jobs)
        else:
            for c, job in zip(cols, jobs):
                c.collect(srv, *job)
        return srv.stats()

    st_solo, st_shared = run(False), run(True)
    assert st_shared["rows_served"] == st_solo["rows_served"]
    batch_rows_x = (st_shared["mean_batch_rows"]
                    / max(st_solo["mean_batch_rows"], 1e-9))
    assert batch_rows_x > 1.5, (
        f"coalescing only grew mean batch rows {batch_rows_x:.2f}x (<=1.5x)")
    assert st_shared["batches_run"] < st_solo["batches_run"]
    _emit("collector/coalesce2x8", 0.0,
          f"batch_rows_x={batch_rows_x:.2f};"
          f"occupancy={st_shared['occupancy']:.4f}")

    # -- uniform sampler bit-identity vs the pre-refactor draw -------------
    seed, k = 7, 64
    ds = DataServer(seed=seed, blocking=False, prefetch=False,
                    capacity_frames=24 * T, sampler="uniform")
    for i in range(5):
        ds.put({"obs": np.full((4, T, 2), i, np.int32),
                "done": np.zeros((4, T), bool)}, source="bench")
    ref_rng = np.random.default_rng(seed)
    idx = ds.sampler.sample(k)
    ref = (ds._head - ds._size + ref_rng.integers(ds._size, size=k)) \
        % ds._row_slots
    uniform_ok = bool(np.array_equal(idx, ref))
    assert uniform_ok, "uniform sampler diverged from pre-refactor stream"

    record = {
        "env": "rps",
        "arch": "tleague-policy-s",
        "unroll_len": T,
        "served_fps_slots1": round(fps[1], 1),
        "served_fps_slots4": round(fps[4], 1),
        "served_fps_slots16": round(fps[16], 1),
        "slots16_vs_1_speedup_x": round(scaling, 2),
        "coalesce_collectors": n_cols,
        "coalesce_slots_each": E_c,
        "solo_mean_batch_rows": st_solo["mean_batch_rows"],
        "shared_mean_batch_rows": st_shared["mean_batch_rows"],
        "coalesce_batch_rows_x": round(batch_rows_x, 3),
        "solo_occupancy": round(st_solo["occupancy"], 4),
        "shared_occupancy": round(st_shared["occupancy"], 4),
        "uniform_sampler_bit_identical": uniform_ok,
    }
    path = (pathlib.Path(out_path) if out_path
            else _REPO / "BENCH_collector.json")
    _write_bench(path, record)
    _emit("collector/bench_written", 0.0, f"wrote={path.name}")
    if prior is not None:
        _check_against(record, prior, against,
                       floors={"slots16_vs_1_speedup_x": (3.0, 0.5),
                               "coalesce_batch_rows_x": (1.5, 0.5)})
    return record


def fault_recovery(out_path: str | None = None, against: str | None = None):
    """ISSUE 7 acceptance: the robustness plane's three recovery numbers.

      * lease re-issue latency — a task leased to an actor that never
        reports is re-issued to the next requester; measured from issue
        to the re-issued task landing in another actor's hands, under a
        short TTL + a 1-ms reaper cadence (the distributed reaper runs
        at 1 s; the latency scales with TTL + reap interval).
      * pull availability — a ModelPoolClient reading across
        [primary, replica] endpoints while the PRIMARY pool server is
        killed mid-loop: the fraction of pulls that still answer
        (failover to the read replica), plus the worst failover stall.
      * fps dip/recovery — 4 actor threads produce frames; 2 are killed
        mid-run and later replaced: frames/sec before, during the
        2-actor gap, and after replacements join. Recovery ratio is the
        headline (the fleet must come back to its baseline).

    Writes BENCH_fault.json; with `against`, compares to the stored
    record and fails on regression (the CI mode)."""
    import threading

    from repro.actors import Actor
    from repro.configs import get_arch
    from repro.core import LeagueMgr, MatchResult, ModelKey
    from repro.core.model_pool import ModelPool, ModelPoolReplica
    from repro.distributed import transport as tp
    from repro.envs import make_env
    from repro.models import init_params

    prior = (json.loads(pathlib.Path(against).read_text())
             if against else None)
    rng = np.random.default_rng(3)

    # -- (a) lease re-issue latency -----------------------------------------
    ttl, rounds = 0.05, 5
    league = LeagueMgr(lease_ttl_s=ttl)
    league.add_learning_agent(
        "main", {"w": rng.normal(size=(8,)).astype(np.float32)})
    reissue_lat = []
    for _ in range(rounds):
        t_issue = time.monotonic()
        league.request_task("main", actor_id="victim")   # never reported
        while True:
            league.reap_leases()                         # 1-ms reaper cadence
            if league.lease_state()["reissue_queued"]:
                t2 = league.request_task("main", actor_id="spare")
                reissue_lat.append(time.monotonic() - t_issue)
                # the spare finishes its match: complete the lease so only
                # the victim's leases ever expire
                league.report_result(MatchResult(
                    learner_key=t2.learner_key,
                    opponent_keys=t2.opponent_keys, outcome=1.0,
                    episode_len=1, task_id=t2.task_id))
                break
            time.sleep(0.001)
    lstate = league.lease_state()
    assert lstate["reissued"] == rounds and lstate["reaped"] == rounds
    lat_mean = float(np.mean(reissue_lat))
    _emit("fault/lease_reissue", lat_mean * 1e6,
          f"ttl_s={ttl};max_s={max(reissue_lat):.3f}")

    # -- (b) pull availability across a primary kill ------------------------
    params = {f"layer{i}": rng.normal(size=(256, 256)).astype(np.float32)
              for i in range(4)}
    pool = ModelPool()
    key = ModelKey("bench", 0)
    pool.push(key, params)
    primary_srv = tp.RpcServer({"pool": pool}).start()
    fast = tp.RetryPolicy(base_s=0.01, cap_s=0.05, deadline_s=1.0)
    replica = ModelPoolReplica(
        tp.ModelPoolClient(tp.RpcClient(primary_srv.address, retry=fast)),
        sync_interval_s=0.05)
    replica.sync_once()
    replica.start_following()
    replica_srv = tp.RpcServer({"pool": replica}).start()
    client = tp.ModelPoolClient(tp.RpcClient(
        [primary_srv.address, replica_srv.address], retry=fast, seed=0))
    duration, kill_at = 2.0, 1.0
    attempts = failures = 0
    post_kill_ms = []
    t0, killed = time.perf_counter(), False
    try:
        while time.perf_counter() - t0 < duration:
            if not killed and time.perf_counter() - t0 >= kill_at:
                primary_srv.close()                      # kill the primary
                killed = True
            t1 = time.perf_counter()
            attempts += 1
            try:
                client.pull(key)
            except tp.TransportError:
                failures += 1
            if killed:
                post_kill_ms.append((time.perf_counter() - t1) * 1e3)
            time.sleep(0.01)
    finally:
        client.close()
        replica.stop()
        replica_srv.close()
        primary_srv.close()
    availability = (attempts - failures) / max(attempts, 1)
    failover_max_ms = max(post_kill_ms) if post_kill_ms else 0.0
    assert availability >= 0.95, (
        f"pull availability {availability:.3f} < 0.95 across primary kill")
    _emit("fault/pull_availability", failover_max_ms * 1e3,
          f"availability={availability:.3f};attempts={attempts}")

    # -- (c) fps dip and recovery across a 2-of-4 actor kill ----------------
    env = make_env("rps")
    cfg = get_arch("tleague-policy-s")
    league2 = LeagueMgr()
    league2.add_learning_agent("main", init_params(jax.random.PRNGKey(0), cfg))
    E, T, n_actors = 8, 8, 4
    frames = [0] * (n_actors + 2)        # slot per thread, incl. replacements
    stops = [threading.Event() for _ in range(n_actors + 2)]

    def mk_actor(i):
        return Actor(env, cfg, league2, num_envs=E, unroll_len=T, seed=100 + i)

    def work(i, actor):
        while not stops[i].is_set():
            actor.run_segment()
            frames[i] += E * T

    actors = [mk_actor(i) for i in range(n_actors)]
    spares = [mk_actor(10 + j) for j in range(2)]
    for a in actors + spares:            # compile every actor off the clock
        a.run_segment()
    threads = [threading.Thread(target=work, args=(i, a), daemon=True)
               for i, a in enumerate(actors)]
    for th in threads:
        th.start()

    def window(seconds: float) -> float:
        f0, t0 = sum(frames), time.perf_counter()
        time.sleep(seconds)
        return (sum(frames) - f0) / (time.perf_counter() - t0)

    w = 1.0
    fps_before = window(w)
    for i in (2, 3):                     # kill 2 of 4
        stops[i].set()
    threads[2].join()
    threads[3].join()
    fps_during = window(w)
    for j, a in enumerate(spares):       # prewarmed replacements join
        th = threading.Thread(target=work, args=(n_actors + j, a), daemon=True)
        threads.append(th)
        th.start()
    fps_after = window(w)
    for s in stops:
        s.set()
    for th in threads:
        th.join(timeout=10.0)
    dip_ratio = fps_during / max(fps_before, 1e-9)
    recovery_ratio = fps_after / max(fps_before, 1e-9)
    _emit("fault/fps_recovery", 0.0,
          f"before={fps_before:.0f};during={fps_during:.0f};"
          f"after={fps_after:.0f};recovery_x={recovery_ratio:.2f}")

    record = {
        "lease_ttl_s": ttl,
        "lease_reissue_rounds": rounds,
        "lease_reissue_latency_s_mean": round(lat_mean, 4),
        "lease_reissue_latency_s_max": round(max(reissue_lat), 4),
        "pull_attempts": attempts,
        "pull_failures": failures,
        "pull_availability": round(availability, 4),
        "pull_failover_max_ms": round(failover_max_ms, 2),
        "replica_sync_cycles": replica.sync_stats["cycles"],
        "actors": n_actors,
        "actors_killed": 2,
        "fps_before": round(fps_before, 1),
        "fps_during_kill": round(fps_during, 1),
        "fps_after_recovery": round(fps_after, 1),
        "fps_dip_ratio": round(dip_ratio, 3),
        "fps_recovery_ratio": round(recovery_ratio, 3),
        "backend": jax.default_backend(),
    }
    path = pathlib.Path(out_path) if out_path else _REPO / "BENCH_fault.json"
    _write_bench(path, record)
    _emit("fault/bench_written", 0.0, f"wrote={path.name}")
    if prior is not None:
        _check_against(record, prior, against,
                       floors={"pull_availability": (0.95, 0.9),
                               "fps_recovery_ratio": (0.5, 0.5)})
    return record


def kernels():
    from repro.kernels import flash_attention, reverse_discounted_scan, rmsnorm
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 4, 256, 64))
    kk = jax.random.normal(k, (1, 2, 256, 64))
    v = jax.random.normal(k, (1, 2, 256, 64))
    us = _time(lambda: jax.block_until_ready(
        flash_attention(q, kk, v, 0.125, True, 0, 0.0, 128, 128, True)))
    _emit("kernels/flash_attention_256", us, "interpret_mode")
    d = jax.random.normal(k, (32, 128))
    g = jax.random.uniform(k, (32, 128)) * 0.99
    us = _time(lambda: jax.block_until_ready(
        reverse_discounted_scan(d, g, interpret=True)))
    _emit("kernels/vtrace_scan_32x128", us, "interpret_mode")
    x = jax.random.normal(k, (512, 256))
    w = jnp.ones((256,))
    us = _time(lambda: jax.block_until_ready(rmsnorm(x, w, interpret=True)))
    _emit("kernels/rmsnorm_512x256", us, "interpret_mode")


def serving_gateway(out_path: str | None = None, against: str | None = None):
    """ISSUE 9 acceptance: the serving-gateway plane's three numbers.

      * fleet scaling — served rows/sec through a `ServingGateway` at 1
        vs 4 replicas, closed-loop clients. Each replica is an
        `InfServer` whose flush adds a SIMULATED accelerator service
        time (base + per-row, lock held — the replica is busy) on top
        of its real CPU forward: a 1-core CI host cannot colocate four
        real accelerators, so the fleet axis measures what the gateway
        actually adds — concurrent service windows across replicas
        (sleeps release the GIL exactly like a remote device wait). The
        simulated curve is recorded in the artifact; the >=2.5x floor is
        asserted before writing.
      * SLO hit rate — paced open-loop traffic (~50% of the measured
        4-replica capacity) tagged with a deadline bucket, the gateway's
        deadline pump running; p99 latency and hit rate come from
        `stats()["deadlines"]` (>=0.95 asserted).
      * fleet rollout — a frozen `tleague-policy-s` model propagates to
        4 REAL RPC replicas (in-process RpcServers, real wire): cold
        rollout ships every byte once, warm re-rollout `has_model`-probes
        and ships ZERO bytes (asserted).
    """
    import threading

    from repro.configs import get_arch
    from repro.core import ModelKey
    from repro.distributed.transport import InfServerBackend, RpcServer
    from repro.infserver import InfServer
    from repro.models import init_params
    from repro.params.manifest import build_manifest
    from repro.serving import ServingGateway
    from repro.serving.fleet import connect

    arch = "tleague-policy-s"
    cfg = get_arch(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = ModelKey("main", 0)
    manifest = build_manifest(params, version=0)
    obs_len, rows_per_submit = 26, 8
    svc_base_s, svc_per_row_s = 0.015, 0.00005

    class SimReplica(InfServer):
        """Real InfServer + simulated accelerator service time: the
        flush sleeps (base + per_row x queued) under the server lock
        before running the real CPU forward."""

        def flush(self):
            with self._lock:
                rows = self.queue_depth
                if rows:
                    time.sleep(svc_base_s + svc_per_row_s * rows)
                super().flush()

    def make_fleet(n):
        fleet = []
        for i in range(n):
            r = SimReplica(cfg, 6, max_batch=64, seed=i)
            r.register_model(key, params, content_hash=manifest.tree_hash,
                             version=0)
            r.get(r.submit(np.zeros((rows_per_submit, obs_len), np.int32),
                           model=key))          # warm the jit cache
            fleet.append(r)
        return fleet

    def drive_closed(gw, n_clients, seconds):
        """Closed-loop: each client thread submits and waits, repeat."""
        obs = np.zeros((rows_per_submit, obs_len), np.int32)
        stop = threading.Event()
        served = [0] * n_clients

        def client(i):
            while not stop.is_set():
                gw.get(gw.submit(obs, model=key))
                served[i] += rows_per_submit

        ts = [threading.Thread(target=client, args=(i,))
              for i in range(n_clients)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        time.sleep(seconds)
        stop.set()
        for t in ts:
            t.join()
        return sum(served) / (time.perf_counter() - t0)

    # -- axis 1: fleet scaling ------------------------------------------------
    fleet_rates = {}
    for n in (1, 4):
        gw = ServingGateway(make_fleet(n), router="least_loaded",
                            max_inflight_rows=100_000)
        fleet_rates[n] = drive_closed(gw, n_clients=2 * n, seconds=3.0)
        _emit(f"serving/fleet{n}", 1e6 * rows_per_submit / fleet_rates[n],
              f"rows_per_s={fleet_rates[n]:.0f}")
    fleet_speedup = fleet_rates[4] / fleet_rates[1]
    _emit("serving/fleet_speedup", 0.0, f"x4_vs_x1={fleet_speedup:.2f}")
    assert fleet_speedup >= 2.5, \
        f"fleet scaling below floor: {fleet_speedup:.2f}x < 2.5x"

    # -- axis 2: SLO deadline buckets under paced open-loop load --------------
    deadline_s = 0.1
    offered = 0.5 * fleet_rates[4]
    gw = ServingGateway(make_fleet(4), router="least_loaded",
                        max_inflight_rows=4096,
                        deadline_edges_s=(0.025, 0.1, 0.5)).start()
    n_clients = 8
    interval = n_clients * rows_per_submit / offered
    stop = threading.Event()

    def paced(i):
        nxt = time.perf_counter() + (i / n_clients) * interval
        while not stop.is_set():
            lag = nxt - time.perf_counter()
            if lag > 0:
                time.sleep(lag)
            gw.get(gw.submit(np.zeros((rows_per_submit, obs_len), np.int32),
                             model=key, deadline_s=deadline_s))
            nxt += interval

    ts = [threading.Thread(target=paced, args=(i,)) for i in range(n_clients)]
    for t in ts:
        t.start()
    time.sleep(4.0)
    stop.set()
    for t in ts:
        t.join()
    gw.stop()
    slo = gw.stats()["deadlines"][gw.deadlines.label(deadline_s)]
    _emit("serving/slo_p99", slo["p99_ms"] * 1e3,
          f"hit_rate={slo['hit_rate']:.3f}")
    assert slo["hit_rate"] >= 0.95, \
        f"deadline hit rate {slo['hit_rate']:.3f} < 0.95"

    # -- axis 3: fleet rollout over real RPC ----------------------------------
    servers = [InfServer(cfg, 6, max_batch=64, seed=i) for i in range(4)]
    rpcs = [RpcServer({"inf": InfServerBackend(s)}).start() for s in servers]
    try:
        gw = ServingGateway([connect(r.address) for r in rpcs])
        cold = gw.rollout(key, params, manifest)
        warm = gw.rollout(key, params, manifest)
        assert warm["bytes_shipped"] == 0, \
            f"warm rollout shipped {warm['bytes_shipped']} bytes"
        assert cold["shipped_to"] == 4 and warm["already_hosted"] == 4
    finally:
        for r in rpcs:
            r.close()
    _emit("serving/rollout_cold", cold["propagation_ms"] * 1e3,
          f"bytes={cold['bytes_shipped']}")
    _emit("serving/rollout_warm", warm["propagation_ms"] * 1e3, "bytes=0")

    record = {
        "arch": arch,
        "rows_per_submit": rows_per_submit,
        "sim_service_base_ms": svc_base_s * 1e3,
        "sim_service_per_row_us": svc_per_row_s * 1e6,
        "fleet_rows_per_s_1": fleet_rates[1],
        "fleet_rows_per_s_4": fleet_rates[4],
        "fleet_speedup_x": fleet_speedup,
        "slo_deadline_ms": deadline_s * 1e3,
        "slo_offered_rows_per_s": offered,
        "slo_requests": slo["count"],
        "slo_p99_ms": slo["p99_ms"],
        "slo_hit_rate": slo["hit_rate"],
        "rollout_replicas": 4,
        "rollout_model_mb": manifest.nbytes / 2**20,
        "rollout_cold_ms": cold["propagation_ms"],
        "rollout_cold_bytes": cold["bytes_shipped"],
        "rollout_warm_ms": warm["propagation_ms"],
        "rollout_warm_bytes": warm["bytes_shipped"],
    }
    out = pathlib.Path(out_path) if out_path else _REPO / "BENCH_serving.json"
    if against:
        prior = json.loads(pathlib.Path(against).read_text())
        _check_against(record, prior, against, floors={
            "fleet_speedup_x": (2.5, 0.5),
            "slo_hit_rate": (0.95, 0.9),
        })
    else:
        _write_bench(out, record)


def transport_throughput(out_path: str | None = None,
                         against: str | None = None):
    """ISSUE 10 acceptance: the transport plane's three headline axes.

      * pipelining  — small-call throughput over ONE connection with a
                      sliding window of 1 / 8 / 64 requests in flight vs
                      the strict serial v1 loop, against a seam with a
                      500 us service time (what real dispatches cost:
                      BENCH_sharded puts request_task at ~340 us and
                      inf_round at ~2800 us); the serial loop eats
                      service + RTT per call, the pipelined connection
                      overlaps them across the server's worker pool.
                      Depth 64 must be >= 3x serial.
      * shm fast path — collector-sized frames (a trajectory segment,
                      MBs of ndarray rows) shipped same-host through the
                      shared-memory ring vs forced TCP chunked
                      streaming; >= 2x frames/sec (one memcpy into the
                      ring vs kernel round trips per 256 KiB chunk)
      * seam re-run — the BENCH_sharded rpc_seams axis (pool_pull /
                      request_task / inf_round) re-timed on the
                      pipelined transport, so the seam-overhead
                      trajectory stays comparable across PRs

    Writes BENCH_transport.json; with `against`, compares to the stored
    record and fails on regression (the CI mode)."""
    import collections

    from repro.configs import get_arch
    from repro.core import LeagueMgr, ModelKey
    from repro.distributed import transport as tp
    from repro.infserver import InfServer
    from repro.models import init_params

    prior = (json.loads(pathlib.Path(against).read_text())
             if against else None)

    class Sink:
        """Echo for small calls; swallow-and-ack for frame shipping
        (mirrors actor->DataServer put: rows go one way, a tiny ack
        comes back)."""

        SVC_S = 0.0005            # 500 us of backend service per call

        @staticmethod
        def echo(x):
            return x

        @classmethod
        def work(cls, x):
            time.sleep(cls.SVC_S)     # models the seam's dispatch cost
            return x

        @staticmethod
        def take(traj):
            return int(next(iter(traj.values())).shape[0])
        # like DataServer.put*: consumes during dispatch, never retains —
        # eligible for zero-copy delivery from the shm ring
        take.__func__._zero_copy_ok = True

    # -- (a) pipelined vs serial small calls ---------------------------------
    n_calls = 600

    def serial_cps(client):
        t0 = time.perf_counter()
        for i in range(n_calls):
            client.call("b.work", i)
        return n_calls / (time.perf_counter() - t0)

    def windowed_cps(client, depth):
        q = collections.deque()
        t0 = time.perf_counter()
        for i in range(n_calls):
            q.append(client.call_async("b.work", i))
            if len(q) >= depth:
                q.popleft().result(timeout=60.0)
        while q:
            q.popleft().result(timeout=60.0)
        return n_calls / (time.perf_counter() - t0)

    with tp.RpcServer({"b": Sink()}) as srv:
        v1 = tp.RpcClient(srv.address, pipeline=False)
        serial = serial_cps(v1)                   # warm
        serial = max(serial_cps(v1) for _ in range(2))
        v1.close()
        c = tp.RpcClient(srv.address)
        depth_cps = {}
        for depth in (1, 8, 64):
            windowed_cps(c, depth)                # warm
            depth_cps[depth] = max(windowed_cps(c, depth) for _ in range(2))
            _emit(f"transport/pipelined_depth{depth}", 1e6 / depth_cps[depth],
                  f"calls_per_s={depth_cps[depth]:.0f};svc_us=500")
        c.close()
    _emit("transport/serial", 1e6 / serial,
          f"calls_per_s={serial:.0f};svc_us=500")
    pipeline_x = depth_cps[64] / serial
    _emit("transport/pipeline_speedup", 0.0, f"depth64_x={pipeline_x:.2f}")
    assert pipeline_x >= 3.0, \
        f"pipelined depth-64 only {pipeline_x:.2f}x serial (< 3x)"

    # -- (b) shm ring vs TCP chunked streaming, collector-sized frames -------
    rows, T, obs_dim = 64, 16, 1024
    traj = {"obs": np.random.default_rng(0)
            .normal(size=(rows, T, obs_dim)).astype(np.float32),
            "actions": np.zeros((rows, T), np.int32)}      # ~4 MB of rows
    frame_bytes = sum(a.nbytes for a in traj.values())
    n_frames = 64

    def frames_per_s(client):
        t0 = time.perf_counter()
        for _ in range(n_frames):
            assert client.call("b.take", traj) == rows
        return n_frames / (time.perf_counter() - t0)

    fps = {}
    with tp.RpcServer({"b": Sink()}) as srv:
        for name, kw in (("tcp", {"shm": False}), ("shm", {})):
            client = tp.RpcClient(srv.address, **kw)
            frames_per_s(client)                  # warm + negotiate
            fps[name] = max(frames_per_s(client) for _ in range(2))
            st = client.transport_stats()
            _emit(f"transport/{name}_frames", 1e6 / fps[name],
                  f"frames_per_s={fps[name]:.1f};"
                  f"MBps={fps[name] * frame_bytes / 2**20:.0f};"
                  f"shm_blobs={st['shm_blobs']}")
            if name == "shm":
                assert st["shm_blobs"] > 0, "shm path never engaged"
            client.close()
    shm_x = fps["shm"] / fps["tcp"]
    _emit("transport/shm_speedup", 0.0, f"x={shm_x:.2f}")
    assert shm_x >= 2.0, f"shm only {shm_x:.2f}x TCP (< 2x)"

    # -- (c) BENCH_sharded rpc_seams axis on the pipelined transport ---------
    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    obs1 = np.zeros((1, 26), np.int32)
    league = LeagueMgr()
    league.add_learning_agent("main", params)
    inf = InfServer(cfg, 6, params, max_batch=8)
    inf.get(inf.submit(obs1))                     # compile off the clock
    srv = tp.serve_league(league, inf)
    lg = tp.LeagueMgrClient(srv.address)
    ic = tp.InfServerClient(tp.RpcClient(srv.address))
    key = ModelKey("main", 0)
    try:
        seams = {
            "pool_pull": lambda: lg.model_pool.pull(key),
            "request_task": lambda: lg.request_task("main"),
            "inf_round": lambda: ic.get(ic.submit(obs1)),
        }
        rpc_seams = {}
        for name, fn in seams.items():
            us = _time(fn, iters=16)
            rpc_seams[name] = {"rpc_us": round(us, 2)}
            _emit(f"transport/rpc_{name}", us, "pipelined")
    finally:
        srv.close()

    record = {
        "codec": tp.CODEC,
        "proto": tp._PROTO,
        "serial_cps": round(serial, 1),
        "pipelined_1_cps": round(depth_cps[1], 1),
        "pipelined_8_cps": round(depth_cps[8], 1),
        "pipelined_64_cps": round(depth_cps[64], 1),
        "pipeline_speedup_64x": round(pipeline_x, 2),
        "frame_bytes": frame_bytes,
        "tcp_fps": round(fps["tcp"], 2),
        "shm_fps": round(fps["shm"], 2),
        "shm_speedup_x": round(shm_x, 2),
        "rpc_seams": rpc_seams,
    }
    out = (pathlib.Path(out_path) if out_path
           else _REPO / "BENCH_transport.json")
    if against:
        _check_against(record, prior, against, floors={
            # the acceptance ratios are ABSOLUTE floors; the raw rates get
            # a loose relative bar (runner classes differ)
            "pipeline_speedup_64x": (3.0, 0.0),
            "shm_speedup_x": (2.0, 0.0),
            "pipelined_64_cps": (1000.0, 0.4),
            "shm_fps": (20.0, 0.4),
        })
    else:
        _write_bench(out, record)
    return record


BENCHES = ("table3_throughput", "table3_scaleup", "seed_infserver",
           "infserver_throughput", "learner_throughput", "league_throughput",
           "sharded_serving", "param_plane", "collector_throughput",
           "fault_recovery", "serving_gateway", "transport_throughput",
           "kernels", "fig4_winrate", "table12_league_eval")

# benches whose record supports the `--against FILE` regression gate
_AGAINST_BENCHES = ("param_plane", "collector_throughput", "fault_recovery",
                    "learner_throughput", "serving_gateway",
                    "transport_throughput")


def main() -> None:
    """`python benchmarks/run.py [bench ...]` — no args runs everything.
    `--against FILE` (with a bench that supports it: param_plane or
    collector_throughput) re-runs and fails on regression vs the stored
    record."""
    argv = list(sys.argv[1:])
    against = None
    if "--against" in argv:
        i = argv.index("--against")
        assert i + 1 < len(argv), "--against needs a FILE argument"
        against = argv[i + 1]
        del argv[i:i + 2]
        assert any(n in argv for n in _AGAINST_BENCHES), \
            "--against needs an explicit bench that supports it " \
            f"(one of {_AGAINST_BENCHES})"
    chosen = argv or list(BENCHES)
    unknown = [n for n in chosen if n not in BENCHES]
    assert not unknown, f"unknown benches {unknown}; pick from {BENCHES}"
    print("name,us_per_call,derived", flush=True)
    for name in chosen:
        if name in _AGAINST_BENCHES and against:
            globals()[name](against=against)
        else:
            globals()[name]()
    if argv:
        return
    # roofline table (from dry-run artifacts, if present)
    try:
        from benchmarks import roofline
        recs = roofline.load_all()
        for r in recs:
            if "skip" in r:
                continue
            step_us = max(r["compute_s"], r["memory_s"],
                          r["collective_s"]) * 1e6
            _emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", step_us,
                  f"bottleneck={r['bottleneck']};useful={r['useful_frac']:.2f}")
    except Exception as e:
        print(f"# roofline skipped: {e}")


if __name__ == '__main__':
    main()
