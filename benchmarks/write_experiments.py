"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON artifacts. §Perf and §Paper-validation are hand-written (they
narrate hypothesis->change->measure cycles and claim comparisons).

  PYTHONPATH=src python -m benchmarks.write_experiments > experiments_tables.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import analyze, load_all

GIB = 1 << 30


def dryrun_table(dirpath="experiments/dryrun"):
    rows = ["| arch | shape | mesh | kind | status | compile_s | "
            "args_GiB/dev | temp_GiB/dev | HLO coll ops (ag/ar/rs/a2a/cp) |",
            "|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(f))
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                        f"SKIP ({r['reason'][:40]}...) | | | | |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r.get('kind','?')} | FAIL | | | | |")
            continue
        mem = r.get("memory", {})
        arg = mem.get("argument_size_in_bytes", 0) / GIB
        tmp = mem.get("temp_size_in_bytes", 0) / GIB
        c = r["collectives"]
        ops = (f"{c['n_all-gather']}/{c['n_all-reduce']}/"
               f"{c['n_reduce-scatter']}/{c['n_all-to-all']}/"
               f"{c['n_collective-permute']}")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | OK | "
            f"{r.get('compile_s', 0):.0f} | {arg:.2f} | {tmp:.2f} | {ops} |")
    return "\n".join(rows)


def roofline_table(dirpath="experiments/dryrun"):
    recs = load_all(dirpath)
    rows = ["| arch | shape | kind | compute_s | memory_s | collective_s | "
            "bottleneck | MODEL/HLO flops | roofline note |",
            "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        "compute": "at the MXU roof; raise useful_frac (less remat/redundancy)",
        "memory": "HBM-bound; fuse/cast or shrink the working set",
        "collective": "ICI-bound; reshard to cut gathers or overlap",
    }
    for r in recs:
        if "skip" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | | "
                        f"{r['skip'][:40]} |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['bottleneck']}** | "
            f"{r['useful_frac']:.2f} | {notes[r['bottleneck']]} |")
    return "\n".join(rows)


def main():
    print("## §Dry-run (generated)\n")
    print(dryrun_table())
    print("\n## §Roofline (generated, single-pod 16x16 = 256 chips)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
